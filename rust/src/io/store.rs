//! On-disk MPS store ("FMPS1").
//!
//! Layout:
//! ```text
//! <dir>/manifest.json      — format/version, spec echo, per-site shapes,
//!                            precision, codec, blob sizes
//! <dir>/site_<i>.bin       — Γ_i as interleaved (re, im) pairs, row-major
//!                            (χ_l, χ_r, d), in the manifest precision,
//!                            optionally LZ-compressed (`util::compress`)
//! ```
//!
//! FP16 blobs implement §3.3.2: stored/moved at half width, converted back
//! to f32/f64 before contraction (precision is *not* recovered — that loss
//! is part of the design and is what the precision tests measure).

use std::fs;
use std::path::{Path, PathBuf};

use crate::mps::gbs::GbsSpec;
use crate::mps::qubit::QubitSpec;
use crate::mps::workload::{WorkloadKind, WorkloadSpec};
use crate::mps::{Mps, Site};
use crate::tensor::{Complex, Tensor3, C64};
use crate::util::compress;
use crate::util::error::{Error, Result};
use crate::util::f16;
use crate::util::json::Json;

/// Element precision of the stored blobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorePrecision {
    F64,
    F32,
    F16,
}

impl StorePrecision {
    pub fn bytes_per_scalar(self) -> usize {
        match self {
            StorePrecision::F64 => 8,
            StorePrecision::F32 => 4,
            StorePrecision::F16 => 2,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            StorePrecision::F64 => "f64",
            StorePrecision::F32 => "f32",
            StorePrecision::F16 => "f16",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f64" => Ok(StorePrecision::F64),
            "f32" => Ok(StorePrecision::F32),
            "f16" => Ok(StorePrecision::F16),
            _ => Err(Error::config(format!("unknown precision '{s}'"))),
        }
    }
}

/// Blob compression. `Lz` is the built-in LZ77 codec ([`compress`]); the
/// string "zstd" is accepted as a legacy alias for it (the offline build
/// has no zstd crate, and no stores were ever written with real zstd).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreCodec {
    Raw,
    Lz,
}

impl StoreCodec {
    pub fn as_str(self) -> &'static str {
        match self {
            StoreCodec::Raw => "raw",
            StoreCodec::Lz => "lz",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "raw" => Ok(StoreCodec::Raw),
            "lz" | "zstd" => Ok(StoreCodec::Lz),
            _ => Err(Error::config(format!("unknown codec '{s}'"))),
        }
    }
}

/// Tensor-parallel shard identity of a store (docs/TENSOR_PARALLEL.md).
///
/// A shard store is a complete, self-contained FMPS1 store whose every
/// site Γ keeps the **full** left bond but only a contiguous range of
/// right-bond (χ_r) columns. The manifest records which slice it is and
/// the bonds of the parent, so a TP leader can recompute every member's
/// column ranges (via [`shard_range`]) from its own manifest alone.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardInfo {
    /// Content key (manifest hash) of the unsharded parent store.
    pub base: u64,
    /// This shard's rank position, `0 ≤ index < of`.
    pub index: usize,
    /// Total shards the parent was split into (the TP group size).
    pub of: usize,
    /// (χ_l, χ_r) per site of the *parent* store.
    pub full_bonds: Vec<(usize, usize)>,
}

/// Balanced contiguous column range `[lo, hi)` of shard `k` of `g` over a
/// bond of width `y`: the first `y % g` shards get one extra column, so
/// widths differ by at most one and concatenating the ranges in rank
/// order reproduces `0..y` exactly. A narrow bond (`y < g`, e.g. the
/// chain ends where χ_r = 1) legally yields zero-width ranges.
pub fn shard_range(y: usize, k: usize, g: usize) -> (usize, usize) {
    debug_assert!(g > 0 && k < g);
    let q = y / g;
    let r = y % g;
    let lo = k * q + k.min(r);
    let hi = lo + q + usize::from(k < r);
    (lo, hi)
}

/// An opened on-disk MPS.
#[derive(Debug, Clone)]
pub struct GammaStore {
    pub dir: PathBuf,
    pub spec: WorkloadSpec,
    pub precision: StorePrecision,
    pub codec: StoreCodec,
    /// (χ_l, χ_r) per site.
    pub bonds: Vec<(usize, usize)>,
    /// Compressed blob size per site (bytes actually read from disk).
    pub blob_bytes: Vec<u64>,
    /// Present when this store is one column shard of a parent store.
    pub shard: Option<ShardInfo>,
}

impl GammaStore {
    /// Generate the MPS from `spec` and write it site-by-site (streaming:
    /// only one site is in memory at a time).
    pub fn create(
        dir: &Path,
        spec: impl Into<WorkloadSpec>,
        precision: StorePrecision,
        codec: StoreCodec,
    ) -> Result<GammaStore> {
        let spec: WorkloadSpec = spec.into();
        fs::create_dir_all(dir).map_err(|e| Error::io(dir.display(), e))?;
        let plan = spec.chi_plan();
        let m = spec.m();
        let mut bonds = Vec::with_capacity(m);
        let mut blob_bytes = Vec::with_capacity(m);
        let mut chi_l = 1usize;
        for i in 0..m {
            let site = spec.generate_site(i, chi_l, &plan)?;
            let blob = encode_site(&site.gamma, precision, codec)?;
            let path = site_path(dir, i);
            fs::write(&path, &blob).map_err(|e| Error::io(path.display(), e))?;
            bonds.push((chi_l, site.chi_r()));
            blob_bytes.push(blob.len() as u64);
            chi_l = site.chi_r();
        }
        let store = GammaStore {
            dir: dir.to_path_buf(),
            spec,
            precision,
            codec,
            bonds,
            blob_bytes,
            shard: None,
        };
        store.write_manifest()?;
        Ok(store)
    }

    /// Write an already-materialized MPS (tests / conversions).
    pub fn create_from_mps(
        dir: &Path,
        spec: impl Into<WorkloadSpec>,
        mps: &Mps,
        precision: StorePrecision,
        codec: StoreCodec,
    ) -> Result<GammaStore> {
        fs::create_dir_all(dir).map_err(|e| Error::io(dir.display(), e))?;
        let mut bonds = Vec::new();
        let mut blob_bytes = Vec::new();
        for (i, site) in mps.sites.iter().enumerate() {
            let blob = encode_site(&site.gamma, precision, codec)?;
            let path = site_path(dir, i);
            fs::write(&path, &blob).map_err(|e| Error::io(path.display(), e))?;
            bonds.push((site.chi_l(), site.chi_r()));
            blob_bytes.push(blob.len() as u64);
        }
        let store = GammaStore {
            dir: dir.to_path_buf(),
            spec: spec.into(),
            precision,
            codec,
            bonds,
            blob_bytes,
            shard: None,
        };
        store.write_manifest()?;
        Ok(store)
    }

    pub fn open(dir: &Path) -> Result<GammaStore> {
        let mpath = dir.join("manifest.json");
        let text = fs::read_to_string(&mpath).map_err(|e| Error::io(mpath.display(), e))?;
        let j = Json::parse(&text)?;
        if j.req("magic")?.as_str() != Some("FMPS1") {
            return Err(Error::format("bad magic (want FMPS1)"));
        }
        let spec = spec_from_json(j.req("spec")?)?;
        let precision = StorePrecision::parse(
            j.req("precision")?
                .as_str()
                .ok_or_else(|| Error::format("precision not a string"))?,
        )?;
        let codec = StoreCodec::parse(
            j.req("codec")?
                .as_str()
                .ok_or_else(|| Error::format("codec not a string"))?,
        )?;
        let bonds: Vec<(usize, usize)> = j
            .req("bonds")?
            .as_arr()
            .ok_or_else(|| Error::format("bonds not an array"))?
            .iter()
            .map(|b| {
                let pair = b.as_arr().ok_or_else(|| Error::format("bond not a pair"))?;
                Ok((
                    pair[0].as_usize().ok_or_else(|| Error::format("bond[0]"))?,
                    pair[1].as_usize().ok_or_else(|| Error::format("bond[1]"))?,
                ))
            })
            .collect::<Result<_>>()?;
        let blob_bytes: Vec<u64> = j
            .req("blob_bytes")?
            .as_arr()
            .ok_or_else(|| Error::format("blob_bytes not an array"))?
            .iter()
            .map(|b| {
                b.as_f64()
                    .map(|v| v as u64)
                    .ok_or_else(|| Error::format("blob size"))
            })
            .collect::<Result<_>>()?;
        if bonds.len() != spec.m() || blob_bytes.len() != spec.m() {
            return Err(Error::format("manifest site count mismatch"));
        }
        // Optional TP shard section; absent on every unsharded store
        // (and on stores written by pre-TP builds, which also never
        // *read* it — unknown manifest keys are ignored on both sides).
        let shard = match j.get("shard") {
            None | Some(Json::Null) => None,
            Some(sj) => Some(shard_from_json(sj, spec.m())?),
        };
        if let Some(s) = &shard {
            for (i, &(l, _)) in bonds.iter().enumerate() {
                let (full_l, full_r) = s.full_bonds[i];
                let (lo, hi) = shard_range(full_r, s.index, s.of);
                if l != full_l || bonds[i].1 != hi - lo {
                    return Err(Error::format(format!(
                        "shard manifest: site {i} bonds {:?} disagree with \
                         shard {}/{} of full bonds ({full_l},{full_r})",
                        bonds[i], s.index, s.of
                    )));
                }
            }
        }
        Ok(GammaStore {
            dir: dir.to_path_buf(),
            spec,
            precision,
            codec,
            bonds,
            blob_bytes,
            shard,
        })
    }

    fn write_manifest(&self) -> Result<()> {
        let mut fields = vec![
            ("magic", Json::Str("FMPS1".into())),
            ("version", Json::Num(1.0)),
            ("precision", Json::Str(self.precision.as_str().into())),
            ("codec", Json::Str(self.codec.as_str().into())),
            ("spec", spec_to_json(&self.spec)),
            (
                "bonds",
                Json::Arr(
                    self.bonds
                        .iter()
                        .map(|&(l, r)| {
                            Json::Arr(vec![Json::Num(l as f64), Json::Num(r as f64)])
                        })
                        .collect(),
                ),
            ),
            (
                "blob_bytes",
                Json::Arr(
                    self.blob_bytes
                        .iter()
                        .map(|&b| Json::Num(b as f64))
                        .collect(),
                ),
            ),
        ];
        // The shard section makes every shard's manifest — and therefore
        // its content key — distinct even when two shards slice to
        // identical bytes (uniform χ divisible by the group size).
        if let Some(s) = &self.shard {
            fields.push(("shard", shard_to_json(s)));
        }
        let j = Json::obj(fields);
        let path = self.dir.join("manifest.json");
        fs::write(&path, j.pretty()).map_err(|e| Error::io(path.display(), e))
    }

    /// Write shard `index` of `of` of this store to `dir`: a complete
    /// FMPS1 store whose site `i` keeps the full χ_l rows of Γ_i but only
    /// the [`shard_range`] columns of its χ_r axis (layout is row-major
    /// (χ_l, χ_r, d) with d innermost, so a χ_r range is a contiguous
    /// column block of the (χ_l, χ_r·d) GEMM view — the PR 5 split).
    /// Streaming: one site is in memory at a time. Slicing decoded values
    /// and re-encoding at the same precision round-trips bit-exactly, so
    /// a shard's Γ is bitwise the column slice of the parent's.
    pub fn write_shard(&self, dir: &Path, index: usize, of: usize) -> Result<GammaStore> {
        if self.shard.is_some() {
            return Err(Error::config("cannot shard a store that is already a shard"));
        }
        if of < 2 || index >= of {
            return Err(Error::config(format!(
                "bad shard index {index} of {of} (need of ≥ 2, index < of)"
            )));
        }
        let base = self.manifest_hash()?;
        fs::create_dir_all(dir).map_err(|e| Error::io(dir.display(), e))?;
        let m = self.spec.m();
        let mut bonds = Vec::with_capacity(m);
        let mut blob_bytes = Vec::with_capacity(m);
        for i in 0..m {
            let site = self.load_site(i)?;
            let (chi_l, chi_r) = self.bonds[i];
            let (lo, hi) = shard_range(chi_r, index, of);
            let sliced = site.gamma.slice_d1(lo, hi)?;
            let blob = encode_site(&sliced, self.precision, self.codec)?;
            let path = site_path(dir, i);
            fs::write(&path, &blob).map_err(|e| Error::io(path.display(), e))?;
            bonds.push((chi_l, hi - lo));
            blob_bytes.push(blob.len() as u64);
        }
        let store = GammaStore {
            dir: dir.to_path_buf(),
            spec: self.spec.clone(),
            precision: self.precision,
            codec: self.codec,
            bonds,
            blob_bytes,
            shard: Some(ShardInfo {
                base,
                index,
                of,
                full_bonds: self.bonds.clone(),
            }),
        };
        store.write_manifest()?;
        Ok(store)
    }

    pub fn num_sites(&self) -> usize {
        self.spec.m()
    }

    /// FNV-1a hash of the manifest bytes — the identity key the service's
    /// `StoreCache` uses, so the same store reached through two paths (or
    /// symlinks) shares one cached entry, while a regenerated store gets a
    /// fresh one.
    pub fn manifest_hash(&self) -> Result<u64> {
        manifest_hash_at(&self.dir)
    }

    /// Bytes on disk for site `i` (what the disk model charges).
    pub fn site_bytes(&self, i: usize) -> u64 {
        self.blob_bytes[i]
    }

    pub fn total_bytes(&self) -> u64 {
        self.blob_bytes.iter().sum()
    }

    /// Load one site. The Λ vector is reconstructed as all-ones (the store
    /// keeps right-canonical states; a future version can persist Λ).
    pub fn load_site(&self, i: usize) -> Result<Site> {
        if i >= self.spec.m() {
            return Err(Error::shape(format!("site {i} ≥ M={}", self.spec.m())));
        }
        let path = site_path(&self.dir, i);
        let blob = fs::read(&path).map_err(|e| Error::io(path.display(), e))?;
        let (chi_l, chi_r) = self.bonds[i];
        let gamma = decode_site(&blob, chi_l, chi_r, self.spec.d(), self.precision, self.codec)?;
        Ok(Site {
            lambda: vec![1.0; chi_r],
            gamma,
        })
    }

    /// Cheap integrity check of the blob files against the manifest:
    /// every site file must exist with exactly its recorded byte count.
    /// The push path runs this before installing a received store, so a
    /// stream that delivered a valid manifest but missing or truncated
    /// blobs is rejected instead of dedup-poisoning its content key.
    /// (Does not decode blob contents — `load_site` still validates
    /// shapes and codec framing on first use.)
    pub fn verify_blobs(&self) -> Result<()> {
        for i in 0..self.spec.m() {
            let path = site_path(&self.dir, i);
            let meta = fs::metadata(&path).map_err(|e| Error::io(path.display(), e))?;
            if meta.len() != self.blob_bytes[i] {
                return Err(Error::format(format!(
                    "site {i} blob is {} bytes, manifest records {}",
                    meta.len(),
                    self.blob_bytes[i]
                )));
            }
        }
        Ok(())
    }

    /// Load the full chain (small scales only).
    pub fn load_all(&self) -> Result<Mps> {
        let sites = (0..self.spec.m())
            .map(|i| self.load_site(i))
            .collect::<Result<Vec<_>>>()?;
        let mps = Mps {
            sites,
            d: self.spec.d(),
        };
        mps.check()?;
        Ok(mps)
    }
}

fn site_name(i: usize) -> String {
    format!("site_{i:05}.bin")
}

fn site_path(dir: &Path, i: usize) -> PathBuf {
    dir.join(site_name(i))
}

/// FNV-1a over the manifest file of the store at `dir` (see
/// [`GammaStore::manifest_hash`]).
pub fn manifest_hash_at(dir: &Path) -> Result<u64> {
    let path = dir.join("manifest.json");
    let bytes = fs::read(&path).map_err(|e| Error::io(path.display(), e))?;
    Ok(crate::util::fnv1a(&bytes))
}

// ---------------------------------------------------------------------------
// FMSS: the serialized store stream behind the chunked push path
// (`net::push`). A self-delimiting concatenation of the manifest and every
// site blob:
//
// ```text
// stream := "FMSS" | varint n_files | file*
// file   := varint name_len | name (UTF-8, no path separators)
//         | varint data_len | data
// ```
//
// The manifest comes first so receivers can validate identity early; blobs
// follow in site order. `StoreStreamSource` produces the stream
// incrementally (one open file at a time — constant memory regardless of
// store size); `StoreStreamWriter` is the receiving state machine, writing
// files into a staging directory as bytes arrive at arbitrary chunk
// boundaries.
// ---------------------------------------------------------------------------

/// Magic prefix of a serialized store stream.
pub const STREAM_MAGIC: [u8; 4] = *b"FMSS";

/// Upper bound on files in one stream (a store has M + 1).
const MAX_STREAM_FILES: u64 = 1 << 20;

fn varint_len(mut v: u64) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

/// Sending half of the FMSS stream (see the section comment above).
pub struct StoreStreamSource {
    dir: PathBuf,
    /// `(name, size)` in stream order.
    files: Vec<(String, u64)>,
    next_file: usize,
    /// Header bytes not yet emitted.
    pending: Vec<u8>,
    pending_pos: usize,
    /// Open file + bytes remaining in it.
    current: Option<(fs::File, u64)>,
    total: u64,
}

impl StoreStreamSource {
    /// Open the store at `dir` for streaming. Validates it parses as an
    /// FMPS1 store first, so a push can never ship a broken directory.
    pub fn open(dir: &Path) -> Result<StoreStreamSource> {
        let store = GammaStore::open(dir)?;
        let mut files = Vec::with_capacity(store.num_sites() + 1);
        for name in std::iter::once("manifest.json".to_string())
            .chain((0..store.num_sites()).map(site_name))
        {
            let path = dir.join(&name);
            let meta = fs::metadata(&path).map_err(|e| Error::io(path.display(), e))?;
            files.push((name, meta.len()));
        }
        let mut total = (STREAM_MAGIC.len() + varint_len(files.len() as u64)) as u64;
        for (name, size) in &files {
            total += (varint_len(name.len() as u64) + name.len() + varint_len(*size)) as u64
                + *size;
        }
        let mut pending = Vec::with_capacity(16);
        pending.extend_from_slice(&STREAM_MAGIC);
        compress::write_varint(&mut pending, files.len() as u64);
        Ok(StoreStreamSource {
            dir: dir.to_path_buf(),
            files,
            next_file: 0,
            pending,
            pending_pos: 0,
            current: None,
            total,
        })
    }

    /// Exact length of the full stream in bytes (known up front — file
    /// sizes come from metadata, headers are deterministic).
    pub fn total_len(&self) -> u64 {
        self.total
    }

    /// Fill `buf` with the next stream bytes; returns the count written
    /// (0 = end of stream).
    pub fn read_chunk(&mut self, buf: &mut [u8]) -> Result<usize> {
        let mut n = 0usize;
        while n < buf.len() {
            if self.pending_pos < self.pending.len() {
                let take = (self.pending.len() - self.pending_pos).min(buf.len() - n);
                buf[n..n + take]
                    .copy_from_slice(&self.pending[self.pending_pos..self.pending_pos + take]);
                self.pending_pos += take;
                n += take;
                continue;
            }
            if let Some((f, remaining)) = self.current.as_mut() {
                if *remaining == 0 {
                    self.current = None;
                    continue;
                }
                let want = (buf.len() - n).min(usize::try_from(*remaining).unwrap_or(usize::MAX));
                let got = std::io::Read::read(f, &mut buf[n..n + want])
                    .map_err(|e| Error::io("store stream read", e))?;
                if got == 0 {
                    // The file shrank after the size was recorded: the
                    // announced total would be wrong — abort loudly.
                    return Err(Error::format("store blob shrank while streaming"));
                }
                *remaining -= got as u64;
                n += got;
                continue;
            }
            if self.next_file >= self.files.len() {
                break; // end of stream
            }
            let (name, size) = self.files[self.next_file].clone();
            self.next_file += 1;
            self.pending.clear();
            self.pending_pos = 0;
            compress::write_varint(&mut self.pending, name.len() as u64);
            self.pending.extend_from_slice(name.as_bytes());
            compress::write_varint(&mut self.pending, size);
            let path = self.dir.join(&name);
            let f = fs::File::open(&path).map_err(|e| Error::io(path.display(), e))?;
            self.current = Some((f, size));
        }
        Ok(n)
    }
}

#[derive(Debug, Clone, Copy)]
enum WriterState {
    Magic,
    NFiles,
    NameLen,
    Name { len: usize },
    DataLen,
    Data { remaining: u64 },
    Done,
}

/// Accumulate one varint across feed boundaries; `Ok(None)` = need more
/// bytes.
fn take_stream_varint(header: &mut Vec<u8>, b: &mut &[u8]) -> Result<Option<u64>> {
    while let Some((&first, rest)) = b.split_first() {
        header.push(first);
        *b = rest;
        if header.len() > 10 {
            return Err(Error::format("store stream: varint overflow"));
        }
        if first & 0x80 == 0 {
            let (v, n) = compress::read_varint(header).map_err(Error::format)?;
            debug_assert_eq!(n, header.len());
            header.clear();
            return Ok(Some(v));
        }
    }
    Ok(None)
}

fn validate_stream_name(name: &str) -> Result<()> {
    let ok = !name.is_empty()
        && !name.starts_with('.')
        && !name.contains("..")
        && name
            .bytes()
            .all(|c| c.is_ascii_alphanumeric() || c == b'.' || c == b'_' || c == b'-');
    if !ok {
        return Err(Error::format(format!(
            "store stream: unsafe file name '{name}'"
        )));
    }
    Ok(())
}

/// Receiving half of the FMSS stream: feed bytes in arbitrary-sized
/// pieces; files are created under `dir` as their headers complete.
/// Rejects path-escaping names, implausible counts, and data after the
/// final file. The caller owns cleanup of `dir` on failure.
pub struct StoreStreamWriter {
    dir: PathBuf,
    state: WriterState,
    /// Bytes buffered while a header (magic/varint/name) completes.
    header: Vec<u8>,
    current_name: String,
    current: Option<fs::File>,
    n_files: u64,
    files_done: u64,
}

impl StoreStreamWriter {
    pub fn new(dir: &Path) -> Result<StoreStreamWriter> {
        fs::create_dir_all(dir).map_err(|e| Error::io(dir.display(), e))?;
        Ok(StoreStreamWriter {
            dir: dir.to_path_buf(),
            state: WriterState::Magic,
            header: Vec::new(),
            current_name: String::new(),
            current: None,
            n_files: 0,
            files_done: 0,
        })
    }

    /// True once exactly `n_files` complete files have been written.
    pub fn finished(&self) -> bool {
        matches!(self.state, WriterState::Done)
    }

    fn close_current_file(&mut self) -> WriterState {
        self.current = None;
        self.files_done += 1;
        if self.files_done == self.n_files {
            WriterState::Done
        } else {
            WriterState::NameLen
        }
    }

    pub fn feed(&mut self, mut b: &[u8]) -> Result<()> {
        while !b.is_empty() {
            match self.state {
                WriterState::Magic => {
                    let take = (STREAM_MAGIC.len() - self.header.len()).min(b.len());
                    self.header.extend_from_slice(&b[..take]);
                    b = &b[take..];
                    if self.header.len() == STREAM_MAGIC.len() {
                        if self.header[..] != STREAM_MAGIC {
                            return Err(Error::format("store stream: bad magic (want FMSS)"));
                        }
                        self.header.clear();
                        self.state = WriterState::NFiles;
                    }
                }
                WriterState::NFiles => {
                    if let Some(v) = take_stream_varint(&mut self.header, &mut b)? {
                        if v == 0 || v > MAX_STREAM_FILES {
                            return Err(Error::format(format!(
                                "store stream: implausible file count {v}"
                            )));
                        }
                        self.n_files = v;
                        self.state = WriterState::NameLen;
                    }
                }
                WriterState::NameLen => {
                    if let Some(v) = take_stream_varint(&mut self.header, &mut b)? {
                        if v == 0 || v > 255 {
                            return Err(Error::format(format!(
                                "store stream: implausible name length {v}"
                            )));
                        }
                        self.state = WriterState::Name { len: v as usize };
                    }
                }
                WriterState::Name { len } => {
                    let take = (len - self.header.len()).min(b.len());
                    self.header.extend_from_slice(&b[..take]);
                    b = &b[take..];
                    if self.header.len() == len {
                        let name = std::str::from_utf8(&self.header)
                            .map_err(|_| Error::format("store stream: name not UTF-8"))?;
                        validate_stream_name(name)?;
                        self.current_name = name.to_string();
                        self.header.clear();
                        self.state = WriterState::DataLen;
                    }
                }
                WriterState::DataLen => {
                    if let Some(v) = take_stream_varint(&mut self.header, &mut b)? {
                        let path = self.dir.join(&self.current_name);
                        let f =
                            fs::File::create(&path).map_err(|e| Error::io(path.display(), e))?;
                        self.current = Some(f);
                        self.state = if v == 0 {
                            // Zero-length file: complete immediately so a
                            // stream ending on it still finishes.
                            self.close_current_file()
                        } else {
                            WriterState::Data { remaining: v }
                        };
                    }
                }
                WriterState::Data { remaining } => {
                    let take = usize::try_from(remaining).unwrap_or(usize::MAX).min(b.len());
                    let f = self.current.as_mut().expect("file open in Data state");
                    std::io::Write::write_all(f, &b[..take])
                        .map_err(|e| Error::io("store stream write", e))?;
                    b = &b[take..];
                    let remaining = remaining - take as u64;
                    self.state = if remaining == 0 {
                        self.close_current_file()
                    } else {
                        WriterState::Data { remaining }
                    };
                }
                WriterState::Done => {
                    return Err(Error::format("store stream: data after final file"));
                }
            }
        }
        Ok(())
    }
}

fn encode_site(g: &Tensor3<f64>, precision: StorePrecision, codec: StoreCodec) -> Result<Vec<u8>> {
    let mut raw: Vec<u8> = Vec::with_capacity(g.len() * 2 * precision.bytes_per_scalar());
    match precision {
        StorePrecision::F64 => {
            for z in &g.data {
                raw.extend_from_slice(&z.re.to_le_bytes());
                raw.extend_from_slice(&z.im.to_le_bytes());
            }
        }
        StorePrecision::F32 => {
            for z in &g.data {
                raw.extend_from_slice(&(z.re as f32).to_le_bytes());
                raw.extend_from_slice(&(z.im as f32).to_le_bytes());
            }
        }
        StorePrecision::F16 => {
            for z in &g.data {
                raw.extend_from_slice(&f16::f32_to_f16_bits(z.re as f32).to_le_bytes());
                raw.extend_from_slice(&f16::f32_to_f16_bits(z.im as f32).to_le_bytes());
            }
        }
    }
    match codec {
        StoreCodec::Raw => Ok(raw),
        StoreCodec::Lz => Ok(compress::compress(&raw)),
    }
}

fn decode_site(
    blob: &[u8],
    chi_l: usize,
    chi_r: usize,
    d: usize,
    precision: StorePrecision,
    codec: StoreCodec,
) -> Result<Tensor3<f64>> {
    let raw: Vec<u8> = match codec {
        StoreCodec::Raw => blob.to_vec(),
        StoreCodec::Lz => compress::decompress(blob).map_err(Error::format)?,
    };
    let n = chi_l * chi_r * d;
    let want = n * 2 * precision.bytes_per_scalar();
    if raw.len() != want {
        return Err(Error::format(format!(
            "site blob: {} bytes, expected {want} for ({chi_l},{chi_r},{d}) {}",
            raw.len(),
            precision.as_str()
        )));
    }
    let mut data = Vec::with_capacity(n);
    match precision {
        StorePrecision::F64 => {
            for c in raw.chunks_exact(16) {
                let re = f64::from_le_bytes(c[0..8].try_into().unwrap());
                let im = f64::from_le_bytes(c[8..16].try_into().unwrap());
                data.push(C64::new(re, im));
            }
        }
        StorePrecision::F32 => {
            for c in raw.chunks_exact(8) {
                let re = f32::from_le_bytes(c[0..4].try_into().unwrap());
                let im = f32::from_le_bytes(c[4..8].try_into().unwrap());
                data.push(C64::new(re as f64, im as f64));
            }
        }
        StorePrecision::F16 => {
            for c in raw.chunks_exact(4) {
                let re = f16::f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
                let im = f16::f16_bits_to_f32(u16::from_le_bytes([c[2], c[3]]));
                data.push(Complex::new(re as f64, im as f64));
            }
        }
    }
    Tensor3::from_vec(chi_l, chi_r, d, data)
}

fn shard_to_json(s: &ShardInfo) -> Json {
    Json::obj(vec![
        ("base", Json::Str(format!("{:016x}", s.base))),
        ("index", Json::Num(s.index as f64)),
        ("of", Json::Num(s.of as f64)),
        (
            "full_bonds",
            Json::Arr(
                s.full_bonds
                    .iter()
                    .map(|&(l, r)| Json::Arr(vec![Json::Num(l as f64), Json::Num(r as f64)]))
                    .collect(),
            ),
        ),
    ])
}

fn shard_from_json(j: &Json, m: usize) -> Result<ShardInfo> {
    let base = j
        .req("base")?
        .as_str()
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .ok_or_else(|| Error::format("shard.base is not a hex key"))?;
    let index = j
        .req("index")?
        .as_usize()
        .ok_or_else(|| Error::format("shard.index"))?;
    let of = j.req("of")?.as_usize().ok_or_else(|| Error::format("shard.of"))?;
    if of < 2 || index >= of {
        return Err(Error::format(format!("implausible shard {index} of {of}")));
    }
    let full_bonds: Vec<(usize, usize)> = j
        .req("full_bonds")?
        .as_arr()
        .ok_or_else(|| Error::format("shard.full_bonds not an array"))?
        .iter()
        .map(|b| {
            let pair = b
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| Error::format("shard bond not a pair"))?;
            Ok((
                pair[0].as_usize().ok_or_else(|| Error::format("shard bond[0]"))?,
                pair[1].as_usize().ok_or_else(|| Error::format("shard bond[1]"))?,
            ))
        })
        .collect::<Result<_>>()?;
    if full_bonds.len() != m {
        return Err(Error::format("shard.full_bonds site count mismatch"));
    }
    Ok(ShardInfo {
        base,
        index,
        of,
        full_bonds,
    })
}

/// Spec echo in the manifest. The `workload` tag is the dispatch field:
/// **omitted** for GBS (so GBS manifests stay byte-identical to pre-workload
/// builds and keep their content keys), written explicitly for every other
/// workload — which makes a non-GBS manifest's bytes, and therefore its
/// FNV content key, impossible to collide with any GBS store's.
pub(crate) fn spec_to_json(s: &WorkloadSpec) -> Json {
    match s {
        WorkloadSpec::Gbs(g) => gbs_spec_to_json(g),
        WorkloadSpec::Qubit(q) => Json::obj(vec![
            ("workload", Json::Str(WorkloadKind::Qubit.as_str().into())),
            ("name", Json::Str(q.name.clone())),
            ("m", Json::Num(q.m as f64)),
            ("chi_cap", Json::Num(q.chi_cap as f64)),
            ("bias", Json::Num(q.bias)),
            ("seed", Json::Num(q.seed as f64)),
        ]),
    }
}

fn gbs_spec_to_json(s: &GbsSpec) -> Json {
    Json::obj(vec![
        ("name", Json::Str(s.name.clone())),
        ("m", Json::Num(s.m as f64)),
        ("d", Json::Num(s.d as f64)),
        ("chi_cap", Json::Num(s.chi_cap as f64)),
        ("asp", Json::Num(s.asp)),
        ("decay_k", Json::Num(s.decay_k)),
        ("displacement_sigma", Json::Num(s.displacement_sigma)),
        ("branch_skew", Json::Num(s.branch_skew)),
        ("seed", Json::Num(s.seed as f64)),
        ("dynamic_chi", Json::Bool(s.dynamic_chi)),
        (
            "step_ratio_override",
            s.step_ratio_override.map(Json::Num).unwrap_or(Json::Null),
        ),
    ])
}

pub(crate) fn spec_from_json(j: &Json) -> Result<WorkloadSpec> {
    // Absent tag ⇒ GBS: every pre-workload manifest parses unchanged.
    let kind = match j.get("workload") {
        None | Some(Json::Null) => WorkloadKind::Gbs,
        Some(v) => WorkloadKind::parse(
            v.as_str()
                .ok_or_else(|| Error::format("spec.workload not a string"))?,
        )?,
    };
    match kind {
        WorkloadKind::Gbs => Ok(WorkloadSpec::Gbs(gbs_spec_from_json(j)?)),
        WorkloadKind::Qubit => Ok(WorkloadSpec::Qubit(QubitSpec {
            name: j
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::format("spec.name"))?
                .to_string(),
            m: j.req("m")?.as_usize().ok_or_else(|| Error::format("spec.m"))?,
            chi_cap: j
                .req("chi_cap")?
                .as_usize()
                .ok_or_else(|| Error::format("spec.chi_cap"))?,
            bias: j.get("bias").and_then(|v| v.as_f64()).unwrap_or(1.0),
            seed: j
                .req("seed")?
                .as_f64()
                .ok_or_else(|| Error::format("spec.seed"))? as u64,
        })),
    }
}

fn gbs_spec_from_json(j: &Json) -> Result<GbsSpec> {
    Ok(GbsSpec {
        name: j
            .req("name")?
            .as_str()
            .ok_or_else(|| Error::format("spec.name"))?
            .to_string(),
        m: j.req("m")?.as_usize().ok_or_else(|| Error::format("spec.m"))?,
        d: j.req("d")?.as_usize().ok_or_else(|| Error::format("spec.d"))?,
        chi_cap: j
            .req("chi_cap")?
            .as_usize()
            .ok_or_else(|| Error::format("spec.chi_cap"))?,
        asp: j.req("asp")?.as_f64().ok_or_else(|| Error::format("spec.asp"))?,
        decay_k: j
            .req("decay_k")?
            .as_f64()
            .ok_or_else(|| Error::format("spec.decay_k"))?,
        displacement_sigma: j
            .req("displacement_sigma")?
            .as_f64()
            .ok_or_else(|| Error::format("spec.displacement_sigma"))?,
        // Older stores predate the field; default to no skew.
        branch_skew: j.get("branch_skew").and_then(|v| v.as_f64()).unwrap_or(0.0),
        seed: j
            .req("seed")?
            .as_f64()
            .ok_or_else(|| Error::format("spec.seed"))? as u64,
        dynamic_chi: j
            .req("dynamic_chi")?
            .as_bool()
            .ok_or_else(|| Error::format("spec.dynamic_chi"))?,
        step_ratio_override: j.get("step_ratio_override").and_then(|v| v.as_f64()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> GbsSpec {
        GbsSpec {
            name: "store-test".into(),
            m: 6,
            d: 3,
            chi_cap: 8,
            asp: 3.0,
            decay_k: 0.0,
            displacement_sigma: 0.2,
            branch_skew: 0.0,
            seed: 99,
            dynamic_chi: true,
            step_ratio_override: None,
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fastmps-store-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_f64_raw() {
        let dir = tmpdir("f64raw");
        let s = spec();
        let store = GammaStore::create(&dir, &s, StorePrecision::F64, StoreCodec::Raw).unwrap();
        let mem = s.generate().unwrap();
        let loaded = store.load_all().unwrap();
        for (a, b) in mem.sites.iter().zip(&loaded.sites) {
            assert_eq!(a.gamma.data, b.gamma.data); // f64 raw is lossless
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn roundtrip_f16_lz_bounded_error() {
        let dir = tmpdir("f16lz");
        let s = spec();
        let store = GammaStore::create(&dir, &s, StorePrecision::F16, StoreCodec::Lz).unwrap();
        let mem = s.generate().unwrap();
        let loaded = store.load_all().unwrap();
        for (a, b) in mem.sites.iter().zip(&loaded.sites) {
            for (x, y) in a.gamma.data.iter().zip(&b.gamma.data) {
                // f16 relative error ≤ 2^-11 for normal values.
                let err = (*x - *y).abs();
                assert!(err <= x.abs() / 1024.0 + 1e-6, "{x} vs {y}");
            }
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_reads_manifest() {
        let dir = tmpdir("reopen");
        let s = spec();
        let created =
            GammaStore::create(&dir, &s, StorePrecision::F32, StoreCodec::Lz).unwrap();
        let opened = GammaStore::open(&dir).unwrap();
        assert_eq!(opened.precision, StorePrecision::F32);
        assert_eq!(opened.codec, StoreCodec::Lz);
        assert_eq!(opened.bonds, created.bonds);
        assert_eq!(opened.spec.m(), s.m);
        assert_eq!(opened.spec.seed(), s.seed);
        assert_eq!(opened.spec.tag(), "gbs");
        let site = opened.load_site(2).unwrap();
        assert_eq!(site.chi_l(), created.bonds[2].0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn qubit_store_roundtrips_with_manifest_tag() {
        let dir = tmpdir("qubit");
        let q = QubitSpec::new("qstore", 5, 6, 42);
        GammaStore::create(&dir, &q, StorePrecision::F64, StoreCodec::Raw).unwrap();
        let opened = GammaStore::open(&dir).unwrap();
        assert_eq!(opened.spec.tag(), "qubit");
        assert_eq!(
            (opened.spec.m(), opened.spec.d(), opened.spec.seed()),
            (5, 2, 42)
        );
        let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
        assert!(text.contains("\"workload\""), "manifest carries the tag");
        let mem = crate::mps::workload::WorkloadSpec::from(&q).generate().unwrap();
        let loaded = opened.load_all().unwrap();
        assert_eq!(loaded.d, 2);
        for (a, b) in mem.sites.iter().zip(&loaded.sites) {
            assert_eq!(a.gamma.data, b.gamma.data);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gbs_manifest_stays_untagged() {
        // GBS manifests must not grow a workload field: their bytes — and
        // therefore their content keys — stay identical to pre-workload
        // builds, so push dedup and router affinity survive the upgrade.
        let dir = tmpdir("untagged");
        GammaStore::create(&dir, &spec(), StorePrecision::F32, StoreCodec::Raw).unwrap();
        let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
        assert!(!text.contains("workload"));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn f16_storage_halves_f32_bytes() {
        let dir16 = tmpdir("half16");
        let dir32 = tmpdir("half32");
        let s = spec();
        let s16 = GammaStore::create(&dir16, &s, StorePrecision::F16, StoreCodec::Raw).unwrap();
        let s32 = GammaStore::create(&dir32, &s, StorePrecision::F32, StoreCodec::Raw).unwrap();
        assert_eq!(s16.total_bytes() * 2, s32.total_bytes());
        fs::remove_dir_all(&dir16).unwrap();
        fs::remove_dir_all(&dir32).unwrap();
    }

    #[test]
    fn open_missing_fails_cleanly() {
        let err = GammaStore::open(Path::new("/nonexistent/fastmps")).unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }

    #[test]
    fn out_of_range_site_rejected() {
        let dir = tmpdir("range");
        let store =
            GammaStore::create(&dir, &spec(), StorePrecision::F32, StoreCodec::Raw).unwrap();
        assert!(store.load_site(6).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn store_stream_roundtrips_at_odd_chunk_sizes() {
        let dir = tmpdir("stream-src");
        let s = spec();
        let store = GammaStore::create(&dir, &s, StorePrecision::F32, StoreCodec::Lz).unwrap();
        let hash = store.manifest_hash().unwrap();
        for chunk in [1usize, 7, 64, 1 << 16] {
            let out = tmpdir(&format!("stream-dst-{chunk}"));
            let mut src = StoreStreamSource::open(&dir).unwrap();
            let total = src.total_len();
            let mut w = StoreStreamWriter::new(&out).unwrap();
            let mut buf = vec![0u8; chunk];
            let mut moved = 0u64;
            loop {
                let n = src.read_chunk(&mut buf).unwrap();
                if n == 0 {
                    break;
                }
                w.feed(&buf[..n]).unwrap();
                moved += n as u64;
            }
            assert_eq!(moved, total, "total_len is exact (chunk {chunk})");
            assert!(w.finished(), "writer complete (chunk {chunk})");
            assert_eq!(manifest_hash_at(&out).unwrap(), hash, "identity preserved");
            let back = GammaStore::open(&out).unwrap();
            assert_eq!(back.bonds, store.bonds);
            back.load_all().unwrap();
            fs::remove_dir_all(&out).unwrap();
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn store_stream_writer_rejects_hostile_input() {
        use crate::util::compress::write_varint;
        let out = tmpdir("stream-bad");

        // Bad magic.
        let mut w = StoreStreamWriter::new(&out).unwrap();
        assert!(w.feed(b"NOPE").is_err());

        // Path-escaping name ('/' is outside the allowed alphabet).
        let mut evil = Vec::new();
        evil.extend_from_slice(&STREAM_MAGIC);
        write_varint(&mut evil, 1);
        let name = b"../escape";
        write_varint(&mut evil, name.len() as u64);
        evil.extend_from_slice(name);
        let mut w = StoreStreamWriter::new(&out).unwrap();
        assert!(w.feed(&evil).is_err());

        // Zero files is implausible.
        let mut zero = Vec::new();
        zero.extend_from_slice(&STREAM_MAGIC);
        write_varint(&mut zero, 0);
        let mut w = StoreStreamWriter::new(&out).unwrap();
        assert!(w.feed(&zero).is_err());

        // Trailing bytes after the final file.
        let mut tail = Vec::new();
        tail.extend_from_slice(&STREAM_MAGIC);
        write_varint(&mut tail, 1);
        write_varint(&mut tail, 1);
        tail.extend_from_slice(b"f");
        write_varint(&mut tail, 2);
        tail.extend_from_slice(b"ok");
        let mut w = StoreStreamWriter::new(&out).unwrap();
        w.feed(&tail).unwrap();
        assert!(w.finished());
        assert!(w.feed(b"x").is_err(), "data after final file");

        fs::remove_dir_all(&out).unwrap();
    }

    #[test]
    fn shard_ranges_partition_the_bond() {
        for (y, g) in [(7usize, 2usize), (8, 2), (1, 2), (5, 3), (2, 4), (12, 4)] {
            let mut cursor = 0;
            for k in 0..g {
                let (lo, hi) = shard_range(y, k, g);
                assert_eq!(lo, cursor, "contiguous (y={y} g={g} k={k})");
                assert!(hi >= lo);
                cursor = hi;
            }
            assert_eq!(cursor, y, "ranges cover 0..{y} exactly (g={g})");
            // Balanced: widths differ by at most one, wide shards first.
            let widths: Vec<usize> =
                (0..g).map(|k| shard_range(y, k, g)).map(|(l, h)| h - l).collect();
            assert!(widths.windows(2).all(|w| w[0] >= w[1] && w[0] - w[1] <= 1));
        }
    }

    #[test]
    fn shard_stores_slice_gamma_columns_bitwise() {
        let dir = tmpdir("shard-base");
        let s = spec();
        let store = GammaStore::create(&dir, &s, StorePrecision::F32, StoreCodec::Lz).unwrap();
        let base_key = store.manifest_hash().unwrap();
        let g = 2;
        let mut shard_keys = Vec::new();
        for k in 0..g {
            let sdir = tmpdir(&format!("shard-{k}"));
            let shard = store.write_shard(&sdir, k, g).unwrap();
            assert_eq!(shard.spec.seed(), s.seed, "spec (and thus thresholds) copied");
            let info = shard.shard.clone().unwrap();
            assert_eq!((info.base, info.index, info.of), (base_key, k, g));
            assert_eq!(info.full_bonds, store.bonds);
            shard_keys.push(shard.manifest_hash().unwrap());
            // Reopen parses + validates the shard section.
            let reopened = GammaStore::open(&sdir).unwrap();
            assert_eq!(reopened.shard, shard.shard);
            reopened.verify_blobs().unwrap();
            // Every site's Γ is bitwise the column slice of the parent's.
            for i in 0..s.m {
                let full = store.load_site(i).unwrap();
                let (lo, hi) = shard_range(store.bonds[i].1, k, g);
                let want = full.gamma.slice_d1(lo, hi).unwrap();
                let got = reopened.load_site(i).unwrap();
                assert_eq!(got.gamma.data, want.data, "site {i} shard {k}");
                assert_eq!((got.gamma.d0, got.gamma.d1), (want.d0, want.d1));
            }
            fs::remove_dir_all(&sdir).unwrap();
        }
        // Distinct shards get distinct content keys, none equal to the base.
        assert_ne!(shard_keys[0], shard_keys[1]);
        assert!(!shard_keys.contains(&base_key));
        // A shard cannot be sharded again; bad indices are rejected.
        let sdir = tmpdir("shard-again");
        let sh = store.write_shard(&sdir, 0, 2).unwrap();
        assert!(sh.write_shard(&tmpdir("nope"), 0, 2).is_err());
        assert!(store.write_shard(&tmpdir("nope"), 2, 2).is_err());
        assert!(store.write_shard(&tmpdir("nope"), 0, 1).is_err());
        fs::remove_dir_all(&sdir).unwrap();
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_blob_detected() {
        let dir = tmpdir("corrupt");
        let store =
            GammaStore::create(&dir, &spec(), StorePrecision::F32, StoreCodec::Raw).unwrap();
        let p = dir.join("site_00001.bin");
        let mut blob = fs::read(&p).unwrap();
        blob.truncate(blob.len() - 4);
        fs::write(&p, &blob).unwrap();
        assert!(store.load_site(1).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }
}
