//! Γ tensor storage and streaming — the I/O half of the paper's
//! data-parallel revival.
//!
//! Large-scale MPS (χ ~ 10⁴, GB-size tensors per site) cannot live in
//! memory; the sampling loop streams `Γ_i` from disk, and the paper's §3.3.2
//! low-precision storage (FP16 Γ, halving I/O and broadcast bytes) plus
//! compression and double-buffered prefetch are what keep the loop
//! compute-bound (computation-I/O ratio `N₁`, §3.1).
//!
//! - [`GammaStore`]: an on-disk MPS ("FMPS1" format): a JSON manifest plus
//!   one blob per site in f64/f32/f16 × raw/lz.
//! - [`Prefetcher`]: background double-buffered loader (I/O↔compute
//!   overlap of Fig. 3).
//! - [`DiskModel`]: optional bandwidth throttle + contention accounting so
//!   the overlap/scaling studies can reproduce the paper's 5 GB/s NVMe
//!   regime on a machine whose page cache would otherwise hide I/O.

mod diskmodel;
mod loader;
mod store;

pub use diskmodel::DiskModel;
pub use loader::{PrefetchStats, Prefetcher};
pub use store::{
    manifest_hash_at, shard_range, GammaStore, ShardInfo, StoreCodec, StorePrecision,
    StoreStreamSource, StoreStreamWriter, STREAM_MAGIC,
};
