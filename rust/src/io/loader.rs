//! Double-buffered Γ prefetcher — the I/O↔compute overlap of Fig. 3.
//!
//! A background thread walks the requested site order, loads (and decodes)
//! each Γ through the [`DiskModel`], and hands tensors over a bounded
//! channel of depth 2 (the "double buffer" of §3.1): while the consumer
//! contracts site `i`, site `i+1` is being read. If compute is slower than
//! I/O (`T_comp > T_IO`), the channel is always full and the loop never
//! stalls on disk — the condition the paper's macro-batch sizing targets.

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::io::{DiskModel, GammaStore};
use crate::mps::Site;
use crate::util::error::{Error, Result};

/// Accumulated I/O accounting of a [`Prefetcher`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefetchStats {
    pub io_secs: f64,
    pub io_bytes: u64,
    pub stall_secs: f64,
}

/// Handle to a running prefetch thread.
pub struct Prefetcher {
    rx: Option<Receiver<Result<(usize, Site, f64, u64)>>>,
    handle: Option<JoinHandle<()>>,
    /// Accumulated modelled I/O seconds (virtual).
    pub io_secs: f64,
    /// Accumulated on-disk bytes read (what the disk model charged).
    pub io_bytes: u64,
    /// Seconds the *consumer* spent blocked waiting on the channel (stall =
    /// I/O not hidden behind compute).
    pub stall_secs: f64,
}

impl Prefetcher {
    /// Start prefetching `order` (site indices) with a buffer of `depth`
    /// sites (2 = classic double buffer).
    pub fn new(
        store: Arc<GammaStore>,
        disk: Arc<DiskModel>,
        order: Vec<usize>,
        depth: usize,
    ) -> Prefetcher {
        let (tx, rx) = sync_channel::<Result<(usize, Site, f64, u64)>>(depth.max(1));
        let handle = std::thread::spawn(move || {
            for i in order {
                let bytes = store.site_bytes(i);
                let secs = disk.charge(bytes);
                let msg = store.load_site(i).map(|s| (i, s, secs, bytes));
                let failed = msg.is_err();
                if tx.send(msg).is_err() || failed {
                    break; // consumer dropped or error delivered
                }
            }
        });
        Prefetcher {
            rx: Some(rx),
            handle: Some(handle),
            io_secs: 0.0,
            io_bytes: 0,
            stall_secs: 0.0,
        }
    }

    /// Blocking next site; `None` when the order is exhausted.
    pub fn next_site(&mut self) -> Option<Result<(usize, Site)>> {
        let t0 = std::time::Instant::now();
        let rx = self.rx.as_ref()?;
        match rx.recv() {
            Ok(Ok((i, site, secs, bytes))) => {
                self.stall_secs += t0.elapsed().as_secs_f64();
                self.io_secs += secs;
                self.io_bytes += bytes;
                Some(Ok((i, site)))
            }
            Ok(Err(e)) => Some(Err(e)),
            Err(_) => None,
        }
    }

    /// Snapshot of the accumulated I/O accounting (service metrics).
    pub fn stats(&self) -> PrefetchStats {
        PrefetchStats {
            io_secs: self.io_secs,
            io_bytes: self.io_bytes,
            stall_secs: self.stall_secs,
        }
    }

    /// Join the background thread (called on drop too).
    pub fn finish(mut self) -> Result<()> {
        self.join_inner()
    }

    fn join_inner(&mut self) -> Result<()> {
        if let Some(h) = self.handle.take() {
            h.join()
                .map_err(|_| Error::other("prefetcher thread panicked"))?;
        }
        Ok(())
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Drop the receiver first so a producer blocked on the bounded
        // channel errors out of `send` instead of deadlocking, then join.
        drop(self.rx.take());
        let _ = self.join_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::{StoreCodec, StorePrecision};
    use crate::mps::gbs::GbsSpec;

    fn store(tag: &str) -> (Arc<GammaStore>, std::path::PathBuf) {
        let dir =
            std::env::temp_dir().join(format!("fastmps-pref-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = GbsSpec {
            name: "pf".into(),
            m: 8,
            d: 3,
            chi_cap: 6,
            asp: 3.0,
            decay_k: 0.0,
            displacement_sigma: 0.0,
            branch_skew: 0.0,
            seed: 5,
            dynamic_chi: false,
            step_ratio_override: None,
        };
        (
            Arc::new(GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap()),
            dir,
        )
    }

    #[test]
    fn delivers_all_sites_in_order() {
        let (s, dir) = store("order");
        let mut p = Prefetcher::new(s.clone(), DiskModel::unlimited(), (0..8).collect(), 2);
        let mut seen = Vec::new();
        while let Some(r) = p.next_site() {
            let (i, site) = r.unwrap();
            assert_eq!(site.chi_l(), s.bonds[i].0);
            seen.push(i);
        }
        assert_eq!(seen, (0..8).collect::<Vec<_>>());
        p.finish().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repeated_order_supported() {
        // Data-parallel workers walk all M sites once per macro batch.
        let (s, dir) = store("repeat");
        let order: Vec<usize> = (0..8).chain(0..8).collect();
        let mut p = Prefetcher::new(s, DiskModel::unlimited(), order, 2);
        let mut n = 0;
        while let Some(r) = p.next_site() {
            r.unwrap();
            n += 1;
        }
        assert_eq!(n, 16);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn throttled_io_is_accounted() {
        let (s, dir) = store("throttle");
        let disk = DiskModel::throttled(100e6, false); // 100 MB/s, no sleep
        let mut p = Prefetcher::new(s.clone(), disk, vec![0, 1, 2], 2);
        while let Some(r) = p.next_site() {
            r.unwrap();
        }
        let expect: u64 = (0..3).map(|i| s.site_bytes(i)).sum();
        assert!((p.io_secs - expect as f64 / 100e6).abs() < 1e-6);
        assert_eq!(p.stats().io_bytes, expect, "io_bytes is on-disk bytes");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stall_accounted_when_io_is_slower_than_compute() {
        // A sleeping throttle makes every site read really take its
        // modelled time; an instant consumer must therefore be blocked on
        // the channel for most of the walk (§3.1's un-hidden-I/O regime).
        let (s, dir) = store("stall");
        let per_site_secs = s.site_bytes(0) as f64 / 50_000.0;
        let disk = DiskModel::throttled(50_000.0, true);
        let mut p = Prefetcher::new(s.clone(), disk, (0..8).collect(), 2);
        while let Some(r) = p.next_site() {
            r.unwrap();
        }
        let st = p.stats();
        assert!(
            st.stall_secs >= per_site_secs * 3.0,
            "stall {} vs per-site {}",
            st.stall_secs,
            per_site_secs
        );
        let expect_io: f64 = (0..8).map(|i| s.site_bytes(i) as f64 / 50_000.0).sum();
        assert!((st.io_secs - expect_io).abs() < 1e-6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stall_negligible_when_compute_hides_io() {
        // Unthrottled reads + a slow consumer: the depth-2 buffer keeps the
        // producer ahead, so the consumer almost never blocks.
        let (s, dir) = store("hidden");
        let mut p = Prefetcher::new(s, DiskModel::unlimited(), (0..8).collect(), 2);
        let mut compute_secs = 0.0;
        while let Some(r) = p.next_site() {
            r.unwrap();
            std::thread::sleep(std::time::Duration::from_millis(20));
            compute_secs += 0.020;
        }
        let st = p.stats();
        // Loose bound — instant tmpfs reads vs 160 ms of consumer compute;
        // failing needs > 160 ms of scheduler noise across 8 recvs, so the
        // assertion stays deterministic on loaded parallel-CI runners.
        assert!(
            st.stall_secs < compute_secs,
            "stall {} vs compute {}",
            st.stall_secs,
            compute_secs
        );
        assert_eq!(st.io_secs, 0.0); // unthrottled charges nothing
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn early_drop_does_not_hang() {
        let (s, dir) = store("drop");
        let mut p = Prefetcher::new(s, DiskModel::unlimited(), (0..8).collect(), 1);
        let _ = p.next_site();
        drop(p); // must not deadlock on the bounded channel
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
