//! Disk bandwidth model.
//!
//! The paper's overlap analysis (§3.1) lives in the regime "NVMe at
//! ~5 GB/s vs A100 at 156 TFLOPS". On this testbed the page cache makes
//! small reads essentially free, so the I/O-overlap and disk-contention
//! experiments (Fig. 3 pipeline, the baseline's startup contention in
//! Fig. 2) use a throttle: every read is charged `bytes / bandwidth`,
//! multiplied by the number of concurrently reading streams (a simple
//! fair-share contention model). The charge is returned as *virtual
//! seconds* and optionally slept to shape real time.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared disk model; clone the `Arc` into every reader.
#[derive(Debug)]
pub struct DiskModel {
    /// Bytes/second the device sustains; `None` = unthrottled (real disk).
    pub bandwidth: Option<f64>,
    /// Whether to actually sleep (shape wall time) or just account.
    pub sleep: bool,
    readers: AtomicUsize,
}

impl DiskModel {
    /// Unthrottled (pass-through) model.
    pub fn unlimited() -> Arc<DiskModel> {
        Arc::new(DiskModel {
            bandwidth: None,
            sleep: false,
            readers: AtomicUsize::new(0),
        })
    }

    /// Throttled model; `sleep=true` makes reads really take the modelled
    /// time (used by the overlap experiments).
    pub fn throttled(bandwidth_bps: f64, sleep: bool) -> Arc<DiskModel> {
        Arc::new(DiskModel {
            bandwidth: Some(bandwidth_bps),
            sleep,
            readers: AtomicUsize::new(0),
        })
    }

    /// Charge a read of `bytes`; returns the modelled seconds.
    pub fn charge(&self, bytes: u64) -> f64 {
        let Some(bw) = self.bandwidth else {
            return 0.0;
        };
        let active = self.readers.fetch_add(1, Ordering::SeqCst) + 1;
        let secs = bytes as f64 / bw * active as f64;
        if self.sleep {
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
        self.readers.fetch_sub(1, Ordering::SeqCst);
        secs
    }

    /// Current number of in-flight readers (contention probe).
    pub fn active_readers(&self) -> usize {
        self.readers.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_charges_nothing() {
        let m = DiskModel::unlimited();
        assert_eq!(m.charge(1 << 30), 0.0);
    }

    #[test]
    fn throttled_charges_linear() {
        let m = DiskModel::throttled(1e9, false);
        let t = m.charge(500_000_000);
        assert!((t - 0.5).abs() < 1e-9);
    }

    #[test]
    fn contention_multiplies_cost() {
        let m = DiskModel::throttled(1e9, false);
        // Simulate a second in-flight reader.
        m.readers.store(1, Ordering::SeqCst);
        let t = m.charge(1_000_000_000);
        assert!((t - 2.0).abs() < 1e-9, "got {t}");
        m.readers.store(0, Ordering::SeqCst);
    }

    #[test]
    fn sleeping_throttle_shapes_walltime() {
        let m = DiskModel::throttled(10e9, true);
        let t0 = std::time::Instant::now();
        m.charge(100_000_000); // 10 ms at 10 GB/s
        assert!(t0.elapsed().as_secs_f64() >= 0.009);
    }
}
