//! Flight-recorder tracing — per-component ring buffers of span events
//! stitched into end-to-end per-job timelines (docs/OBSERVABILITY.md).
//!
//! Every component that touches a job (client, router, net server, job
//! queue, batcher, worker, engine, sample sink) owns a [`Recorder`]: a
//! **fixed-capacity ring buffer** of [`TraceEvent`] slots, preallocated
//! at construction so that recording at steady state performs **zero
//! heap allocations** — a slot write under a short mutex hold, nothing
//! else. The ring overwrites its oldest events when full (flight
//! recorder, not a log): the last `capacity` events are always
//! retrievable, and `dropped()` says how many rolled off.
//!
//! Timelines are stitched across processes by a **trace id** that rides
//! the job spec over FMPN as an optional JSON field (see
//! docs/PROTOCOL.md § Trace propagation) and by exporting timestamps as
//! absolute unix microseconds: each recorder pins a monotonic
//! [`Instant`] epoch to the wall clock once at construction, so events
//! from different recorders (router and backend, say) sort into one
//! ordered timeline without any clock negotiation.
//!
//! The `trace` control op returns a job's filtered event list;
//! [`render_human`] and [`chrome_trace`] turn that reply into a terminal
//! timeline and Chrome `trace_event` JSON (`chrome://tracing`,
//! Perfetto) respectively — `fastmps trace <job>` wraps both.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

/// Default ring capacity (events) — the `--trace-buf` knob.
pub const DEFAULT_BUF: usize = 4096;

/// Per-site worker spans are sampled: one site in every `SITE_SAMPLE`
/// gets a span, so an M-site chain costs M/16 slots per batch instead
/// of flooding the ring. Job-lifecycle events are always recorded.
pub const SITE_SAMPLE: u64 = 16;

/// Which component recorded an event. The Chrome export maps each layer
/// to its own track (tid) so timelines read top-to-bottom in job order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layer {
    Client,
    Router,
    Net,
    Queue,
    Batcher,
    Worker,
    /// Tensor-parallel collectives (group setup, env broadcast, partial
    /// gather) — see `docs/TENSOR_PARALLEL.md`.
    Tp,
    Engine,
    Sink,
}

impl Layer {
    pub fn as_str(self) -> &'static str {
        match self {
            Layer::Client => "client",
            Layer::Router => "router",
            Layer::Net => "net",
            Layer::Queue => "queue",
            Layer::Batcher => "batcher",
            Layer::Worker => "worker",
            Layer::Tp => "tp",
            Layer::Engine => "engine",
            Layer::Sink => "sink",
        }
    }

    /// Stable per-layer track id for the Chrome export.
    pub fn track(name: &str) -> u64 {
        match name {
            "client" => 1,
            "router" => 2,
            "net" => 3,
            "queue" => 4,
            "batcher" => 5,
            "worker" => 6,
            "engine" => 7,
            "sink" => 8,
            "tp" => 9,
            _ => 10,
        }
    }
}

/// Span phase, mirroring Chrome `trace_event` phases: `Begin`/`End`
/// bracket an open span, `Instant` is a point event, `Complete` is a
/// closed span recorded retroactively with its duration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Begin,
    End,
    Instant,
    Complete,
}

impl EventKind {
    pub fn ph(self) -> &'static str {
        match self {
            EventKind::Begin => "B",
            EventKind::End => "E",
            EventKind::Instant => "i",
            EventKind::Complete => "X",
        }
    }
}

/// One preallocated ring slot. `name` is `&'static str` by design: the
/// hot path must not build strings. `job`/`trace` are 0 when unknown;
/// `arg` is a free-form operand (site index, byte count, backend index).
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Monotonic nanoseconds since the recorder's epoch.
    pub t_ns: u64,
    /// `Complete` spans only: duration in nanoseconds (0 otherwise).
    pub dur_ns: u64,
    /// Per-recorder sequence number — stable tie-break for equal `t_ns`.
    pub seq: u64,
    pub kind: EventKind,
    pub layer: Layer,
    pub name: &'static str,
    pub job: u64,
    pub trace: u64,
    pub arg: u64,
}

impl TraceEvent {
    fn empty() -> TraceEvent {
        TraceEvent {
            t_ns: 0,
            dur_ns: 0,
            seq: 0,
            kind: EventKind::Instant,
            layer: Layer::Net,
            name: "",
            job: 0,
            trace: 0,
            arg: 0,
        }
    }
}

struct Ring {
    slots: Vec<TraceEvent>,
    /// Next write index.
    head: usize,
    /// Total events ever recorded (written - dropped == retained).
    count: u64,
}

/// Fixed-capacity flight recorder. Cheap to record into (one short
/// mutex hold, no allocation), cheap to drain (copy out up to
/// `capacity` events). Capacity 0 disables recording entirely.
pub struct Recorder {
    epoch: Instant,
    epoch_unix_ns: u64,
    ring: Mutex<Ring>,
}

impl Recorder {
    pub fn new(capacity: usize) -> Recorder {
        Recorder {
            epoch: Instant::now(),
            epoch_unix_ns: unix_ns(),
            ring: Mutex::new(Ring {
                slots: vec![TraceEvent::empty(); capacity],
                head: 0,
                count: 0,
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.ring.lock().unwrap().slots.len()
    }

    /// Wall-clock nanoseconds corresponding to `t_ns == 0`.
    pub fn epoch_unix_ns(&self) -> u64 {
        self.epoch_unix_ns
    }

    /// Events that rolled off the ring since construction.
    pub fn dropped(&self) -> u64 {
        let r = self.ring.lock().unwrap();
        r.count.saturating_sub(r.slots.len() as u64)
    }

    fn record(
        &self,
        kind: EventKind,
        layer: Layer,
        name: &'static str,
        job: u64,
        trace: u64,
        arg: u64,
        dur_ns: u64,
    ) {
        let t_ns = self.epoch.elapsed().as_nanos() as u64;
        let mut r = self.ring.lock().unwrap();
        let cap = r.slots.len();
        if cap == 0 {
            return;
        }
        let seq = r.count;
        let head = r.head;
        r.slots[head] = TraceEvent {
            t_ns: t_ns.saturating_sub(dur_ns),
            dur_ns,
            seq,
            kind,
            layer,
            name,
            job,
            trace,
            arg,
        };
        r.head = (head + 1) % cap;
        r.count += 1;
    }

    pub fn begin(&self, layer: Layer, name: &'static str, job: u64, trace: u64) {
        self.record(EventKind::Begin, layer, name, job, trace, 0, 0);
    }

    pub fn end(&self, layer: Layer, name: &'static str, job: u64, trace: u64) {
        self.record(EventKind::End, layer, name, job, trace, 0, 0);
    }

    pub fn instant(&self, layer: Layer, name: &'static str, job: u64, trace: u64, arg: u64) {
        self.record(EventKind::Instant, layer, name, job, trace, arg, 0);
    }

    /// A span recorded after the fact: stored at `now - dur` with its
    /// duration, so retroactive spans still sort by their start time.
    pub fn span(
        &self,
        layer: Layer,
        name: &'static str,
        job: u64,
        trace: u64,
        dur_ns: u64,
        arg: u64,
    ) {
        self.record(EventKind::Complete, layer, name, job, trace, arg, dur_ns);
    }

    /// Retained events, oldest first.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let r = self.ring.lock().unwrap();
        let cap = r.slots.len();
        let retained = (r.count as usize).min(cap);
        let mut out = Vec::with_capacity(retained);
        if retained == 0 {
            return out;
        }
        // Oldest slot: `head` once wrapped, index 0 before that.
        let start = if r.count as usize > cap { r.head } else { 0 };
        for i in 0..retained {
            out.push(r.slots[(start + i) % cap]);
        }
        out
    }

    /// Retained events matching a job id and/or trace id (either filter
    /// may be 0 = don't care; both 0 returns everything).
    pub fn events_for(&self, job: u64, trace: u64) -> Vec<TraceEvent> {
        self.snapshot()
            .into_iter()
            .filter(|e| {
                (job == 0 && trace == 0)
                    || (job != 0 && e.job == job)
                    || (trace != 0 && e.trace == trace)
            })
            .collect()
    }

    /// Serialize events as the wire form of the `trace` op: absolute
    /// unix-microsecond timestamps so recorders stitch across hosts.
    pub fn events_json(&self, events: &[TraceEvent]) -> Json {
        Json::Arr(events.iter().map(|e| self.event_json(e)).collect())
    }

    fn event_json(&self, e: &TraceEvent) -> Json {
        let t_us = (self.epoch_unix_ns + e.t_ns) / 1_000;
        let mut pairs = vec![
            ("t_us", Json::Num(t_us as f64)),
            ("seq", Json::Num(e.seq as f64)),
            ("ph", Json::Str(e.kind.ph().to_string())),
            ("layer", Json::Str(e.layer.as_str().to_string())),
            ("name", Json::Str(e.name.to_string())),
        ];
        if e.kind == EventKind::Complete {
            pairs.push(("dur_us", Json::Num(e.dur_ns as f64 / 1_000.0)));
        }
        if e.job != 0 {
            pairs.push(("job", Json::Num(e.job as f64)));
        }
        if e.trace != 0 {
            pairs.push(("trace", Json::Str(format!("{:016x}", e.trace))));
        }
        if e.arg != 0 {
            pairs.push(("arg", Json::Num(e.arg as f64)));
        }
        Json::obj(pairs)
    }
}

fn unix_ns() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0)
}

/// Should this site index get a per-site worker span? (Cheap default
/// sampling: 1 in [`SITE_SAMPLE`].)
pub fn site_sampled(site: u64) -> bool {
    site % SITE_SAMPLE == 0
}

/// Fresh nonzero trace id: wall clock ⊕ pid ⊕ a Weyl-sequenced counter,
/// FNV-mixed. Uniqueness only needs to hold per fleet per retention
/// window, not cryptographically.
pub fn gen_trace_id() -> u64 {
    static CTR: AtomicU64 = AtomicU64::new(0x9e37_79b9_7f4a_7c15);
    let salt = CTR.fetch_add(0x9e37_79b9_7f4a_7c15, Ordering::Relaxed);
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&unix_ns().to_le_bytes());
    bytes[8..].copy_from_slice(&(salt ^ u64::from(std::process::id())).to_le_bytes());
    let id = crate::util::fnv1a(&bytes);
    if id == 0 {
        1
    } else {
        id
    }
}

/// Parse a 16-hex trace id (the wire form); `None` on anything else.
pub fn parse_trace_id(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok().filter(|&t| t != 0)
}

/// Merge event arrays from several recorders (router + backend) into
/// one timeline ordered by (t_us, seq).
pub fn merge_events(mut events: Vec<Json>) -> Vec<Json> {
    let key = |e: &Json| {
        (
            e.get("t_us").and_then(|v| v.as_f64()).unwrap_or(0.0),
            e.get("seq").and_then(|v| v.as_f64()).unwrap_or(0.0),
        )
    };
    events.sort_by(|a, b| {
        key(a)
            .partial_cmp(&key(b))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    events
}

/// Render a `trace` op reply as a terminal timeline: one line per
/// event, offsets relative to the first event.
pub fn render_human(reply: &Json) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let events = reply
        .get("events")
        .and_then(|v| v.as_arr())
        .unwrap_or(&[]);
    let trace = reply.get("trace").and_then(|v| v.as_str()).unwrap_or("-");
    let job = reply.get("job").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let _ = writeln!(
        out,
        "trace {trace} — job {job}, {} event(s)",
        events.len()
    );
    if events.is_empty() {
        out.push_str("  (no events retained — raise --trace-buf?)\n");
        return out;
    }
    let t0 = events
        .iter()
        .filter_map(|e| e.get("t_us").and_then(|v| v.as_f64()))
        .fold(f64::INFINITY, f64::min);
    for e in events {
        let t = e.get("t_us").and_then(|v| v.as_f64()).unwrap_or(t0);
        let layer = e.get("layer").and_then(|v| v.as_str()).unwrap_or("?");
        let name = e.get("name").and_then(|v| v.as_str()).unwrap_or("?");
        let ph = e.get("ph").and_then(|v| v.as_str()).unwrap_or("i");
        let mut detail = String::new();
        match ph {
            "B" => detail.push('▶'),
            "E" => detail.push('◀'),
            "X" => {
                let dur = e.get("dur_us").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let _ = write!(detail, "{:.3} ms", dur / 1_000.0);
            }
            _ => {}
        }
        if let Some(arg) = e.get("arg").and_then(|v| v.as_f64()) {
            let _ = write!(detail, " arg={arg}");
        }
        if let Some(j) = e.get("job").and_then(|v| v.as_f64()) {
            let _ = write!(detail, " job={j}");
        }
        let _ = writeln!(
            out,
            "  +{:>10.3} ms  {layer:<7} {name:<16} {}",
            (t - t0) / 1_000.0,
            detail.trim()
        );
    }
    out
}

/// Convert a `trace` op reply into Chrome `trace_event` JSON (the
/// object form: `{"traceEvents": [...]}`), loadable in
/// `chrome://tracing` and Perfetto. Timestamps are rebased to the first
/// event; each layer gets its own thread track.
pub fn chrome_trace(reply: &Json) -> Json {
    let events = reply
        .get("events")
        .and_then(|v| v.as_arr())
        .unwrap_or(&[]);
    let t0 = events
        .iter()
        .filter_map(|e| e.get("t_us").and_then(|v| v.as_f64()))
        .fold(f64::INFINITY, f64::min);
    let mut out = Vec::with_capacity(events.len());
    for e in events {
        let layer = e.get("layer").and_then(|v| v.as_str()).unwrap_or("?");
        let ph = e.get("ph").and_then(|v| v.as_str()).unwrap_or("i");
        let t = e.get("t_us").and_then(|v| v.as_f64()).unwrap_or(t0);
        let mut pairs = vec![
            (
                "name",
                Json::Str(
                    e.get("name")
                        .and_then(|v| v.as_str())
                        .unwrap_or("?")
                        .to_string(),
                ),
            ),
            ("cat", Json::Str(layer.to_string())),
            ("ph", Json::Str(ph.to_string())),
            ("ts", Json::Num(t - t0)),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(Layer::track(layer) as f64)),
        ];
        if ph == "X" {
            pairs.push((
                "dur",
                Json::Num(e.get("dur_us").and_then(|v| v.as_f64()).unwrap_or(0.0)),
            ));
        }
        if ph == "i" {
            pairs.push(("s", Json::Str("t".to_string())));
        }
        let mut args = Vec::new();
        if let Some(j) = e.get("job") {
            args.push(("job", j.clone()));
        }
        if let Some(t) = e.get("trace") {
            args.push(("trace", t.clone()));
        }
        if let Some(a) = e.get("arg") {
            args.push(("arg", a.clone()));
        }
        if !args.is_empty() {
            pairs.push(("args", Json::obj(args)));
        }
        out.push(Json::obj(pairs));
    }
    Json::obj(vec![("traceEvents", Json::Arr(out))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_records_and_snapshots_in_order() {
        let r = Recorder::new(8);
        r.begin(Layer::Queue, "a", 1, 7);
        r.instant(Layer::Worker, "b", 1, 7, 42);
        r.end(Layer::Queue, "a", 1, 7);
        let evs = r.snapshot();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].name, "a");
        assert_eq!(evs[0].kind, EventKind::Begin);
        assert_eq!(evs[1].arg, 42);
        assert_eq!(evs[2].kind, EventKind::End);
        assert!(evs[0].t_ns <= evs[1].t_ns && evs[1].t_ns <= evs[2].t_ns);
        assert_eq!(evs.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn ring_wraps_keeping_latest() {
        let r = Recorder::new(4);
        for i in 0..10u64 {
            r.instant(Layer::Net, "e", i, 0, 0);
        }
        let evs = r.snapshot();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs.iter().map(|e| e.job).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert_eq!(r.dropped(), 6);
    }

    #[test]
    fn zero_capacity_disables_recording() {
        let r = Recorder::new(0);
        r.instant(Layer::Net, "e", 1, 1, 1);
        assert!(r.snapshot().is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn events_for_filters_by_job_or_trace() {
        let r = Recorder::new(16);
        r.instant(Layer::Queue, "a", 1, 0xaa, 0);
        r.instant(Layer::Queue, "b", 2, 0xbb, 0);
        r.instant(Layer::Client, "c", 0, 0xaa, 0); // job unknown, trace known
        assert_eq!(r.events_for(1, 0).len(), 1);
        assert_eq!(r.events_for(0, 0xaa).len(), 2);
        assert_eq!(r.events_for(1, 0xaa).len(), 2, "either filter matches");
        assert_eq!(r.events_for(0, 0).len(), 3, "no filter returns all");
    }

    #[test]
    fn recording_is_allocation_free() {
        // The tentpole gate: a warm recorder writes into preallocated
        // slots — no heap traffic per event. The counting allocator is
        // process-global; retry for a clean window (other test threads
        // may allocate concurrently).
        let r = Recorder::new(64);
        r.instant(Layer::Engine, "warm", 1, 1, 0);
        let mut clean = false;
        for _ in 0..128 {
            let before = crate::util::alloc::allocation_count();
            r.begin(Layer::Engine, "step", 1, 1);
            r.span(Layer::Engine, "site", 1, 1, 1_000, 3);
            r.end(Layer::Engine, "step", 1, 1);
            if crate::util::alloc::allocation_count() == before {
                clean = true;
                break;
            }
        }
        assert!(clean, "no allocation-free record window observed");
    }

    #[test]
    fn span_backdates_start_by_duration() {
        let r = Recorder::new(8);
        r.span(Layer::Sink, "encode", 1, 1, 5_000_000, 0);
        let e = r.snapshot()[0];
        assert_eq!(e.kind, EventKind::Complete);
        assert_eq!(e.dur_ns, 5_000_000);
        // Start time is now - dur (saturating), so a span recorded
        // immediately after construction backdates toward the epoch.
        assert!(e.t_ns < 5_000_000);
    }

    #[test]
    fn json_export_and_stitch_order() {
        let r = Recorder::new(8);
        r.begin(Layer::Queue, "wait", 3, 0xfeed);
        r.end(Layer::Queue, "wait", 3, 0xfeed);
        let j = r.events_json(&r.snapshot());
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("ph").unwrap().as_str(), Some("B"));
        assert_eq!(arr[0].get("layer").unwrap().as_str(), Some("queue"));
        assert_eq!(arr[0].get("trace").unwrap().as_str(), Some("000000000000feed"));
        let merged = merge_events(arr.to_vec());
        let ts: Vec<f64> = merged
            .iter()
            .map(|e| e.get("t_us").unwrap().as_f64().unwrap())
            .collect();
        assert!(ts[0] <= ts[1]);
    }

    #[test]
    fn render_and_chrome_export_shapes() {
        let r = Recorder::new(8);
        r.instant(Layer::Router, "spillover", 2, 0xabc, 1);
        r.span(Layer::Worker, "batch", 2, 0xabc, 2_000_000, 0);
        let reply = Json::obj(vec![
            ("job", Json::Num(2.0)),
            ("trace", Json::Str("0000000000000abc".into())),
            ("events", r.events_json(&r.snapshot())),
        ]);
        let text = render_human(&reply);
        assert!(text.contains("spillover"), "{text}");
        assert!(text.contains("worker"), "{text}");
        let chrome = chrome_trace(&reply);
        let evs = chrome.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        let x = evs
            .iter()
            .find(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .unwrap();
        assert_eq!(x.get("dur").unwrap().as_f64(), Some(2_000.0));
        assert_eq!(x.get("tid").unwrap().as_f64(), Some(6.0));
        // The whole export must be serializable JSON.
        assert!(Json::parse(&chrome.dump()).is_ok());
    }

    #[test]
    fn trace_ids_are_nonzero_and_distinct() {
        let a = gen_trace_id();
        let b = gen_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
        assert_eq!(parse_trace_id(&format!("{a:016x}")), Some(a));
        assert_eq!(parse_trace_id("zz"), None);
        assert_eq!(parse_trace_id("0"), None);
    }

    #[test]
    fn site_sampling_is_cheap_default() {
        assert!(site_sampled(0));
        assert!(!site_sampled(1));
        assert!(site_sampled(SITE_SAMPLE));
    }
}
