//! Analytic performance models — Eqs. (1), (2), (3), (4), (7) of the paper,
//! plus device rooflines.
//!
//! These serve two purposes: (a) unit-testable encodings of the paper's
//! cost analysis (data parallel beats the fixed-process model parallel
//! scheme; CCR thresholds; overlap conditions), and (b) the machinery that
//! regenerates the paper's A100-scale tables (Table 2) on a CPU-only
//! testbed by anchoring measured FLOP counts to modelled device constants.

use crate::comm::NetModel;

/// Device compute/bandwidth constants.
#[derive(Debug, Clone, Copy)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Peak FLOP/s in the precision the hot loop uses.
    pub flops: f64,
    /// Peak FP64 FLOP/s (for the mixed-precision ablation).
    pub flops_fp64: f64,
    /// Memory bandwidth (B/s).
    pub mem_bw: f64,
    /// Device/global memory capacity (bytes).
    pub mem_capacity: u64,
    /// Sustained storage read bandwidth feeding this device (B/s).
    pub io_bw: f64,
    /// Fraction of peak a well-tuned GEMM achieves (efficiency anchor).
    pub gemm_efficiency: f64,
}

/// NVIDIA A100 (paper §3.3: TF32 156 TFLOPS, FP64 9.5 TFLOPS; §3.1: 5 GB/s
/// NVMe).
pub const A100_TF32: DeviceSpec = DeviceSpec {
    name: "a100-tf32",
    flops: 156e12,
    flops_fp64: 9.5e12,
    mem_bw: 2.0e12,
    mem_capacity: 80 << 30,
    io_bw: 5e9,
    gemm_efficiency: 0.55,
};

/// A100 constrained to FP64 (the ablation's no-mixed-precision arm).
pub const A100_FP64: DeviceSpec = DeviceSpec {
    name: "a100-fp64",
    flops: 9.5e12,
    flops_fp64: 9.5e12,
    mem_bw: 2.0e12,
    mem_capacity: 80 << 30,
    io_bw: 5e9,
    gemm_efficiency: 0.75,
};

/// One Xeon Gold 6230R core (Table 3's testbed), complex f64 path.
pub const XEON_CORE: DeviceSpec = DeviceSpec {
    name: "xeon-6230r-core",
    flops: 70e9,
    flops_fp64: 35e9,
    mem_bw: 20e9,
    mem_capacity: 16 << 30,
    io_bw: 2e9,
    gemm_efficiency: 0.5,
};

/// FLOPs of one site step for a micro batch: contraction `8·N·χl·χr·d`
/// (complex MAC = 8 real FLOPs) plus the measurement reduction `~8·N·χr·d`.
pub fn site_flops(n: u64, chi_l: u64, chi_r: u64, d: u64) -> u64 {
    8 * n * chi_l * chi_r * d + 8 * n * chi_r * d
}

/// Γ bytes at a site for a given scalar width (complex ⇒ 2 scalars).
pub fn gamma_bytes(chi_l: u64, chi_r: u64, d: u64, scalar_bytes: u64) -> u64 {
    chi_l * chi_r * d * 2 * scalar_bytes
}

/// Eq. (3): memory demand of the data-parallel worker, complex double
/// precision by default — `(N₁·χ·d + χ²·d) × 16 B`.
pub fn memory_demand(n1: u64, chi: u64, d: u64, scalar_bytes: u64) -> u64 {
    (n1 * chi * d + chi * chi * d) * 2 * scalar_bytes
}

/// §3.1: computation-to-I/O ratio at one site is `N₁` — overlap holds when
/// `T_comp > T_IO`, i.e. `N₁ > flops_per_byte_ratio` of the device.
pub fn min_macro_batch_for_overlap(dev: &DeviceSpec, scalar_bytes: u64) -> u64 {
    // T_comp = 8·N₁·χ²·d / (eff·flops); T_IO = 2·scalar·χ²·d / io_bw.
    // N₁ > eff·flops·2·scalar / (8·io_bw)
    ((dev.gemm_efficiency * dev.flops * 2.0 * scalar_bytes as f64) / (8.0 * dev.io_bw)).ceil()
        as u64
}

/// §2.2: per-step computation-to-communication ratio of the model-parallel
/// baseline, in the paper's units (complex MACs per byte): compute
/// `N₁·χ²·d` MACs, traffic `N₁·χ·2·scalar` bytes ⇒ `χ·d/(2·scalar)` —
/// "near 3700" for χ=10⁴, d=3, complex64.
pub fn model_parallel_ccr(chi: u64, d: u64, scalar_bytes: u64) -> f64 {
    (chi as f64 * d as f64) / (2.0 * scalar_bytes as f64)
}

/// Parameters shared by the scheme models.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub m: usize,
    pub chi: u64,
    pub d: u64,
    /// Total samples N.
    pub n_total: u64,
    /// Macro batch size N₁.
    pub n1: u64,
    /// Scalar width in the transfer/storage path (2 = fp16).
    pub scalar_bytes: u64,
}

impl Workload {
    pub fn macro_batches(&self) -> u64 {
        self.n_total.div_ceil(self.n1)
    }

    fn t_site_macro(&self, dev: &DeviceSpec) -> f64 {
        site_flops(self.n1, self.chi, self.chi, self.d) as f64
            / (dev.flops * dev.gemm_efficiency)
    }
}

/// Eq. (1): the model-parallel baseline [19] — `p = M` processes, pipeline
/// over macro batches, startup I/O, per-step sends.
pub fn time_model_parallel(w: &Workload, dev: &DeviceSpec, net: &NetModel) -> f64 {
    let n1_batches = w.macro_batches() as f64;
    let t_macro = w.t_site_macro(dev);
    let t_read = gamma_bytes(w.chi, w.chi, w.d, w.scalar_bytes) as f64 / dev.io_bw;
    let t_comm = net.cost_p2p(w.n1 * w.chi * 2 * w.scalar_bytes);
    // T = T_read + n₁·max_i T_i + Σ_i (T_i + T_comm)   (pipeline fill)
    t_read + n1_batches * t_macro + (w.m as f64) * (t_macro + t_comm)
}

/// Eq. (2): the FastMPS data-parallel scheme on `p` workers.
pub fn time_data_parallel(w: &Workload, dev: &DeviceSpec, net: &NetModel, p: usize) -> f64 {
    let t_macro = w.t_site_macro(dev);
    let gamma = gamma_bytes(w.chi, w.chi, w.d, w.scalar_bytes);
    let t_read = gamma as f64 / dev.io_bw;
    let t_bcast = net.cost_bcast(gamma, p);
    // Per worker: n₁/p macro batches × M sites, I/O and bcast overlapped
    // behind compute after the first site.
    let rounds = (w.macro_batches() as f64 / p as f64).ceil();
    t_read + t_bcast + rounds * (w.m as f64) * t_macro
}

/// Eq. (4): per-site time under tensor parallelism over `p2` ranks.
pub fn time_tp_site(
    w: &Workload,
    dev: &DeviceSpec,
    net: &NetModel,
    p2: usize,
    double_site: bool,
) -> f64 {
    let t_gemm = w.t_site_macro(dev) / p2 as f64;
    // Measurement: `8·N₁·χ·d` FLOPs; single-site does it redundantly (×p2
    // overhead per the paper), double-site in parallel but on both sites.
    let t_measure_once = (8 * w.n1 * w.chi * w.d) as f64 / (dev.flops * dev.gemm_efficiency);
    let env_bytes = w.n1 * w.chi * 2 * w.scalar_bytes;
    if double_site {
        // AllReduce every two sites → half the comm per site; measurement
        // runs redundantly on odd sites only (amortized ×1 per site).
        let t_comm = net.cost_allreduce(env_bytes * w.d, p2) / 2.0;
        t_gemm + t_measure_once + t_comm
    } else {
        let t_comm = net.cost_reduce_scatter(env_bytes, p2);
        t_gemm + t_measure_once * p2 as f64 + t_comm
    }
}

/// Eq. (7): tensor-parallel overhead ratio; < 0.1 ⇒ "TP is effective".
pub fn tp_overhead(w: &Workload, dev: &DeviceSpec, net: &NetModel, p2: usize, double_site: bool) -> f64 {
    let t_comp = w.t_site_macro(dev) / p2 as f64;
    let t_measure = (8 * w.n1 * w.chi * w.d) as f64 / (dev.flops * dev.gemm_efficiency);
    let env_bytes = w.n1 * w.chi * 2 * w.scalar_bytes;
    let (t_comm, eta) = if double_site {
        (net.cost_allreduce(env_bytes * w.d, p2) / 2.0, 1.0)
    } else {
        (net.cost_reduce_scatter(env_bytes, p2), p2 as f64)
    };
    (t_comm + eta * t_measure) / (t_comp + t_measure).max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetPreset;

    fn paper_workload() -> Workload {
        Workload {
            m: 288,
            chi: 10_000,
            d: 4,
            n_total: 10_000_000,
            n1: 100_000,
            scalar_bytes: 2,
        }
    }

    #[test]
    fn data_parallel_beats_model_parallel_at_equal_resources() {
        // §3.1's headline claim: with p = M the DP model is strictly faster
        // (no pipeline fill, no per-step comm).
        let w = paper_workload();
        let net = NetPreset::InfinibandHdr.model();
        let t_mp = time_model_parallel(&w, &A100_TF32, &net);
        let t_dp = time_data_parallel(&w, &A100_TF32, &net, w.m);
        assert!(
            t_dp < t_mp,
            "DP {t_dp} should beat MP {t_mp} at p = M = {}",
            w.m
        );
    }

    #[test]
    fn fastmps_8_gpus_vs_baseline_144_shape() {
        // Table 2 shape: FastMPS on 8 GPUs beats the baseline on 144 GPUs
        // for Jiuzhang2-like work (38.57 min vs 62 min in the paper). The
        // baseline [19] runs FP64 with complex-double transfers (mixed
        // precision *is* the FastMPS contribution), FastMPS runs TF32 with
        // FP16 storage.
        let w_fast = Workload {
            m: 144,
            chi: 10_000,
            d: 4,
            n_total: 10_000_000,
            n1: 100_000,
            scalar_bytes: 2,
        };
        let w_base = Workload {
            scalar_bytes: 8,
            ..w_fast
        };
        let net = NetPreset::InfinibandHdr.model();
        let t_dp8 = time_data_parallel(&w_fast, &A100_TF32, &net, 8);
        let t_mp144 = time_model_parallel(&w_base, &A100_FP64, &net);
        let ratio = t_dp8 / t_mp144;
        assert!(
            (0.2..1.5).contains(&ratio),
            "8-GPU DP / 144-GPU MP = {ratio} (paper: 38.57/62 = 0.62)"
        );
    }

    #[test]
    fn mixed_precision_speedup_order_of_magnitude() {
        let w = paper_workload();
        let net = NetPreset::Ideal.model();
        let tf32 = time_data_parallel(&w, &A100_TF32, &net, 8);
        let fp64 = time_data_parallel(&w, &A100_FP64, &net, 8);
        let speedup = fp64 / tf32;
        assert!(
            (5.0..30.0).contains(&speedup),
            "mixed precision speedup {speedup} (peak ratio 156/9.5 ≈ 16)"
        );
    }

    #[test]
    fn overlap_threshold_matches_paper_magnitude() {
        // Paper §3.1: "a safe N₁ should be ~10⁵–10⁶" for A100 + 5 GB/s NVMe.
        let n1 = min_macro_batch_for_overlap(&A100_TF32, 2);
        assert!(
            (5_000..2_000_000).contains(&(n1 as usize)),
            "overlap N₁ = {n1}"
        );
        // CPUs need much smaller macro batches.
        let n1_cpu = min_macro_batch_for_overlap(&XEON_CORE, 2);
        assert!(n1_cpu < n1 / 100, "cpu N₁ = {n1_cpu}");
    }

    #[test]
    fn ccr_near_paper_number() {
        // §2.2: "the exact CCR is near 3700 FLOPs/byte" for χ=10⁴, d≈3,
        // complex64 (8-byte scalars... complex64 = 2×4B).
        let ccr = model_parallel_ccr(10_000, 3, 4);
        assert!((3000.0..4500.0).contains(&ccr), "CCR {ccr}");
    }

    #[test]
    fn double_site_wins_on_nvlink_single_on_symmetric() {
        let w = Workload {
            m: 288,
            chi: 10_000,
            d: 3,
            n_total: 400_000,
            n1: 20_000,
            scalar_bytes: 4,
        };
        let nv = NetPreset::NvLink3.model();
        let od = tp_overhead(&w, &A100_TF32, &nv, 4, true);
        let os = tp_overhead(&w, &A100_TF32, &nv, 4, false);
        assert!(od < os, "NVLink3: double {od} < single {os}");
    }

    #[test]
    fn memory_demand_matches_eq3() {
        // (N₁χd + χ²d)·16B at complex double.
        assert_eq!(memory_demand(1000, 100, 3, 8), (1000 * 100 * 3 + 100 * 100 * 3) * 16);
    }

    #[test]
    fn fp16_halves_gamma_bytes() {
        assert_eq!(
            gamma_bytes(100, 100, 3, 2) * 2,
            gamma_bytes(100, 100, 3, 4)
        );
    }

    #[test]
    fn dp_scales_with_workers() {
        let w = paper_workload();
        let net = NetPreset::InfinibandHdr.model();
        let t1 = time_data_parallel(&w, &A100_TF32, &net, 1);
        let t8 = time_data_parallel(&w, &A100_TF32, &net, 8);
        let eff = t1 / (8.0 * t8);
        assert!(eff > 0.9, "8-way DP efficiency {eff}");
    }

    #[test]
    fn spec_driven_workload_matches_hardwired_gbs_predictions() {
        // `perf-model` used to bake d=4; it now reads d off the preset's
        // GbsSpec. The paper presets all pin d=4, so the spec-driven
        // workload must reproduce the hard-wired predictions bit-for-bit.
        let spec = crate::config::Preset::BorealisM288.full_spec(1);
        let from_spec = Workload {
            m: spec.m,
            chi: spec.chi_cap as u64,
            d: spec.d as u64,
            n_total: 10_000_000,
            n1: 100_000,
            scalar_bytes: 2,
        };
        let hardwired = paper_workload();
        assert_eq!(from_spec.d, hardwired.d);
        let net = NetPreset::InfinibandHdr.model();
        assert_eq!(
            time_data_parallel(&from_spec, &A100_TF32, &net, 8),
            time_data_parallel(&hardwired, &A100_TF32, &net, 8),
        );
        assert_eq!(
            time_model_parallel(&from_spec, &A100_FP64, &net),
            time_model_parallel(&hardwired, &A100_FP64, &net),
        );
        assert_eq!(
            memory_demand(from_spec.n1, from_spec.chi, from_spec.d, 8),
            memory_demand(hardwired.n1, hardwired.chi, hardwired.d, 8),
        );
    }

    #[test]
    fn cost_formulas_scale_with_physical_dimension() {
        // A d=2 qubit workload does strictly less work per site than the
        // d=4 GBS one: fewer FLOPs, smaller Γ tensors, less memory.
        let gbs = paper_workload();
        let qubit = Workload { d: 2, ..gbs };
        assert!(
            site_flops(qubit.n1, qubit.chi, qubit.chi, qubit.d)
                < site_flops(gbs.n1, gbs.chi, gbs.chi, gbs.d)
        );
        assert!(
            gamma_bytes(qubit.n1, qubit.chi, qubit.d, 2)
                < gamma_bytes(gbs.n1, gbs.chi, gbs.d, 2)
        );
        let net = NetPreset::InfinibandHdr.model();
        assert!(
            time_data_parallel(&qubit, &A100_TF32, &net, 8)
                < time_data_parallel(&gbs, &A100_TF32, &net, 8)
        );
        // Exactly proportional where the formula is linear in d (Eq. 3).
        assert_eq!(
            memory_demand(gbs.n1, gbs.chi, 2, 8) * 2,
            memory_demand(gbs.n1, gbs.chi, 4, 8),
        );
    }
}
