//! Deterministic pseudo-random number generation.
//!
//! The paper stresses reproducibility ("obtained strictly consistent
//! sampling results using the same random seeds"), so every stochastic
//! component — dataset generation, measurement thresholds, displacement
//! draws — derives from explicit seeds through SplitMix64 (seeding) and
//! Xoshiro256\*\* (stream). Sample `i` of a run always sees the same draws
//! regardless of process count or batch partitioning: per-sample streams are
//! keyed by `(run_seed, purpose, sample_index)`.

/// SplitMix64 — used to expand one u64 seed into Xoshiro state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

/// Xoshiro256\*\* by Blackman & Vigna — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as the authors recommend.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = sm.next_u64();
        }
        // All-zero state is invalid (fixed point); SplitMix64 never yields
        // four zeros from any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Xoshiro256 { s }
    }

    /// Derive an independent stream for `(purpose, index)` — the key to
    /// partition-invariant sampling.
    pub fn stream(seed: u64, purpose: u64, index: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ purpose.rotate_left(24));
        let a = sm.next_u64();
        Self::seed_from(a ^ index.wrapping_mul(0xd134_2543_de82_ef95))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn unit_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value per call; no caching so
    /// streams stay position-deterministic).
    pub fn normal(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = loop {
            let u = self.unit_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.unit_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Complex standard normal: independent N(0, 1/2) re/im so E|z|² = 1.
    pub fn complex_normal(&mut self) -> (f64, f64) {
        let s = std::f64::consts::FRAC_1_SQRT_2;
        (self.normal() * s, self.normal() * s)
    }

    /// Fill a slice with uniform f32 in [0, 1).
    pub fn fill_unit_f32(&mut self, out: &mut [f32]) {
        for v in out {
            *v = self.unit_f32();
        }
    }
}

/// Purpose tags for derived streams (keep stable across versions: they are
/// part of the reproducibility contract).
pub mod purpose {
    pub const THRESHOLD: u64 = 0x7485_5245_5348; // measurement thresholds
    pub const DISPLACE: u64 = 0x4449_5350_4c41; // displacement draws μ
    pub const DATAGEN: u64 = 0x4441_5441_4745; // synthetic MPS generation
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Xoshiro256::seed_from(42);
        let mut b = Xoshiro256::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from(1);
        let mut b = Xoshiro256::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn unit_in_range() {
        let mut r = Xoshiro256::seed_from(7);
        for _ in 0..10_000 {
            let x = r.unit_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.unit_f32();
            assert!((0.0..1.0).contains(&y));
        }
    }

    #[test]
    fn unit_mean_near_half() {
        let mut r = Xoshiro256::seed_from(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.unit_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn complex_normal_unit_power() {
        let mut r = Xoshiro256::seed_from(13);
        let n = 100_000;
        let p: f64 = (0..n)
            .map(|_| {
                let (re, im) = r.complex_normal();
                re * re + im * im
            })
            .sum::<f64>()
            / n as f64;
        assert!((p - 1.0).abs() < 0.03, "E|z|^2={p}");
    }

    #[test]
    fn streams_independent_of_partition() {
        // Stream for sample 17 is identical no matter which batch it's in.
        let mut a = Xoshiro256::stream(99, purpose::THRESHOLD, 17);
        let mut b = Xoshiro256::stream(99, purpose::THRESHOLD, 17);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = Xoshiro256::stream(99, purpose::THRESHOLD, 18);
        let mut a2 = Xoshiro256::stream(99, purpose::THRESHOLD, 17);
        a2.next_u64();
        assert_ne!(a2.next_u64(), c.next_u64());
    }

    #[test]
    fn purpose_separates_streams() {
        let mut a = Xoshiro256::stream(99, purpose::THRESHOLD, 0);
        let mut b = Xoshiro256::stream(99, purpose::DISPLACE, 0);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
