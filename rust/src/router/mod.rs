//! Store-affinity routing tier for multi-server FMPN fleets.
//!
//! One `NetServer` caps scale-out; the paper's bet (§1, §3) is that data
//! parallelism over samples scales once each worker's working set stays
//! hot. This subsystem revives that bet *across servers*: a gateway that
//! speaks FMPN on both sides (clients need zero changes — `net::frame`
//! is reused verbatim) and places jobs by **rendezvous hashing** on the
//! store's manifest hash, so every job against one MPS lands on the
//! backend whose `StoreCache` already holds it — the placement-aware
//! routing that block-cyclic distributed-MPS work (Adamski & Brown,
//! arXiv:2505.06119) shows keeps per-node working sets hot.
//!
//! - [`rendezvous`] — highest-random-weight placement: adding/removing a
//!   backend moves only the departed backend's keys (≈ 1/N);
//! - [`health`] — per-backend alive/degraded/down state driven by `ping`
//!   probes; down backends leave the rotation until a probe succeeds;
//! - [`gateway`] — the [`Router`]: forwarding of
//!   `submit`/`status`/`wait`/`cancel`/`list`/`metrics`, `Busy`-aware
//!   spillover with retry budget + jitter, graceful drain, per-backend
//!   counters in the metrics registry.
//!
//! Everything is `std::net` + threads — still zero dependencies.

pub mod gateway;
pub mod health;
pub mod rendezvous;

pub use gateway::{Router, RouterStats};
pub use health::{BackendHealth, HealthState};
