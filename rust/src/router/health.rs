//! Per-backend health state, driven by `ping` probes and forward
//! failures.
//!
//! The state machine is a consecutive-failure counter with two
//! thresholds: `degraded_after` failures demote `Alive → Degraded`
//! (still routable, but ranked after every alive backend so new stores
//! prefer healthy nodes), `down_after` demotes to `Down` (excluded from
//! routing entirely). Any success snaps straight back to `Alive` — a
//! backend that answers a ping is servable, whatever its history.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;

use super::rendezvous;

/// Routability of one backend, as the prober last saw it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Probes succeed; first pick for its rendezvous keys.
    Alive,
    /// Some consecutive failures; routable, ranked after alive backends.
    Degraded,
    /// Too many consecutive failures; excluded from routing.
    Down,
}

impl HealthState {
    pub fn as_str(self) -> &'static str {
        match self {
            HealthState::Alive => "alive",
            HealthState::Degraded => "degraded",
            HealthState::Down => "down",
        }
    }

    fn from_u8(v: u8) -> HealthState {
        match v {
            0 => HealthState::Alive,
            1 => HealthState::Degraded,
            _ => HealthState::Down,
        }
    }
}

/// Shared health record for one backend. Lock-free: the prober and every
/// connection thread update it through atomics.
pub struct BackendHealth {
    pub addr: String,
    consecutive_failures: AtomicU32,
    state: AtomicU8,
    pub probes: AtomicU64,
    pub probe_failures: AtomicU64,
    /// Entries *into* `Degraded` / `Down` (state-transition totals,
    /// exposed as `router_health_degraded_total` /
    /// `router_health_down_total` — flapping backends show up here
    /// even when every point-in-time scrape catches them alive).
    pub degraded_transitions: AtomicU64,
    pub down_transitions: AtomicU64,
}

impl BackendHealth {
    /// New backends start `Alive` — the first probe corrects optimism
    /// within one probe interval, and an optimistic start lets a router
    /// serve immediately after boot instead of stalling on a probe round.
    pub fn new(addr: impl Into<String>) -> BackendHealth {
        BackendHealth {
            addr: addr.into(),
            consecutive_failures: AtomicU32::new(0),
            state: AtomicU8::new(HealthState::Alive as u8),
            probes: AtomicU64::new(0),
            probe_failures: AtomicU64::new(0),
            degraded_transitions: AtomicU64::new(0),
            down_transitions: AtomicU64::new(0),
        }
    }

    pub fn state(&self) -> HealthState {
        HealthState::from_u8(self.state.load(Ordering::SeqCst))
    }

    /// Eligible to receive traffic (alive or degraded).
    pub fn routable(&self) -> bool {
        self.state() != HealthState::Down
    }

    /// Store the new state and count the transition when it actually
    /// changed (the `swap` makes each edge counted exactly once even
    /// with prober and connection threads racing).
    fn transition(&self, s: HealthState) {
        let prev = self.state.swap(s as u8, Ordering::SeqCst);
        if prev != s as u8 {
            match s {
                HealthState::Degraded => {
                    self.degraded_transitions.fetch_add(1, Ordering::Relaxed);
                }
                HealthState::Down => {
                    self.down_transitions.fetch_add(1, Ordering::Relaxed);
                }
                HealthState::Alive => {}
            }
        }
    }

    /// A probe or forwarded RPC succeeded.
    pub fn note_ok(&self) {
        self.consecutive_failures.store(0, Ordering::SeqCst);
        self.transition(HealthState::Alive);
    }

    /// A probe or forwarded RPC failed at the transport level. (`Busy`
    /// replies are *not* failures — a busy backend is healthy.)
    pub fn note_failure(&self, degraded_after: u32, down_after: u32) {
        let n = self.consecutive_failures.fetch_add(1, Ordering::SeqCst) + 1;
        let s = if n >= down_after {
            HealthState::Down
        } else if n >= degraded_after {
            HealthState::Degraded
        } else {
            HealthState::Alive
        };
        self.transition(s);
    }

    /// Record one probe outcome (counters + state transition).
    pub fn note_probe(&self, ok: bool, degraded_after: u32, down_after: u32) {
        self.probes.fetch_add(1, Ordering::Relaxed);
        if ok {
            self.note_ok();
        } else {
            self.probe_failures.fetch_add(1, Ordering::Relaxed);
            self.note_failure(degraded_after, down_after);
        }
    }
}

/// Health-aware failover order for `key`: routable backends in
/// rendezvous rank, with every `Alive` backend ahead of every
/// `Degraded` one and `Down` backends excluded.
pub fn failover_order(key: u64, backends: &[Arc<BackendHealth>]) -> Vec<usize> {
    let addrs: Vec<&str> = backends.iter().map(|b| b.addr.as_str()).collect();
    let ranked = rendezvous::rank(key, &addrs);
    let mut alive = Vec::with_capacity(ranked.len());
    let mut degraded = Vec::new();
    for i in ranked {
        match backends[i].state() {
            HealthState::Alive => alive.push(i),
            HealthState::Degraded => degraded.push(i),
            HealthState::Down => {}
        }
    }
    alive.extend(degraded);
    alive
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds_drive_the_state_machine() {
        let h = BackendHealth::new("b:1");
        assert_eq!(h.state(), HealthState::Alive);
        assert!(h.routable());
        h.note_failure(2, 3);
        assert_eq!(h.state(), HealthState::Alive, "1 failure < degraded_after");
        h.note_failure(2, 3);
        assert_eq!(h.state(), HealthState::Degraded);
        assert!(h.routable());
        h.note_failure(2, 3);
        assert_eq!(h.state(), HealthState::Down);
        assert!(!h.routable());
        h.note_ok();
        assert_eq!(h.state(), HealthState::Alive, "one success resurrects");
    }

    #[test]
    fn probes_count_and_transition() {
        let h = BackendHealth::new("b:1");
        h.note_probe(false, 1, 2);
        assert_eq!(h.state(), HealthState::Degraded);
        h.note_probe(false, 1, 2);
        assert_eq!(h.state(), HealthState::Down);
        h.note_probe(true, 1, 2);
        assert_eq!(h.state(), HealthState::Alive);
        assert_eq!(h.probes.load(Ordering::Relaxed), 3);
        assert_eq!(h.probe_failures.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn transitions_count_edges_not_occupancy() {
        let h = BackendHealth::new("b:1");
        // Alive → Degraded → Down: one edge each.
        h.note_failure(1, 3);
        h.note_failure(1, 3);
        assert_eq!(h.state(), HealthState::Degraded);
        assert_eq!(h.degraded_transitions.load(Ordering::Relaxed), 1, "re-entering Degraded while already Degraded is not a transition");
        h.note_failure(1, 3);
        assert_eq!(h.state(), HealthState::Down);
        assert_eq!(h.down_transitions.load(Ordering::Relaxed), 1);
        // Staying Down adds nothing; recovery adds nothing; a second
        // trip through Degraded/Down counts again.
        h.note_failure(1, 3);
        assert_eq!(h.down_transitions.load(Ordering::Relaxed), 1);
        h.note_ok();
        assert_eq!(h.state(), HealthState::Alive);
        h.note_probe(false, 1, 2);
        h.note_probe(false, 1, 2);
        assert_eq!(h.degraded_transitions.load(Ordering::Relaxed), 2);
        assert_eq!(h.down_transitions.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn failover_order_prefers_alive_and_skips_down() {
        let backends: Vec<Arc<BackendHealth>> = (0..4)
            .map(|i| Arc::new(BackendHealth::new(format!("10.0.0.{i}:7733"))))
            .collect();
        let key = 777u64;
        let healthy = failover_order(key, &backends);
        assert_eq!(healthy.len(), 4, "all alive → full rendezvous order");
        let addrs: Vec<&str> = backends.iter().map(|b| b.addr.as_str()).collect();
        assert_eq!(healthy, rendezvous::rank(key, &addrs));

        // Degrade the top pick: it must fall behind every alive backend
        // but stay routable (last).
        let top = healthy[0];
        backends[top].note_failure(1, 3);
        let demoted = failover_order(key, &backends);
        assert_eq!(demoted.len(), 4);
        assert_eq!(*demoted.last().unwrap(), top);
        assert_ne!(demoted[0], top);

        // Take it down entirely: excluded.
        backends[top].note_failure(1, 2);
        let gone = failover_order(key, &backends);
        assert_eq!(gone.len(), 3);
        assert!(!gone.contains(&top));

        // Relative rendezvous order among the survivors is preserved.
        let rest: Vec<usize> = rendezvous::rank(key, &addrs)
            .into_iter()
            .filter(|i| *i != top)
            .collect();
        assert_eq!(gone, rest);
    }
}
