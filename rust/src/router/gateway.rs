//! The routing gateway: an FMPN listener in front of a fleet of FMPN
//! backends.
//!
//! Clients connect to the router exactly as they would to a single
//! `NetServer` — same preamble, same frames, same op vocabulary — so
//! `fastmps submit/jobs/metrics/stop --connect` and `net::Client` work
//! unchanged. Per op:
//!
//! - `submit` resolves the job's store to a routing key
//!   ([`JobSpec::store_key`]) and places it by rendezvous hash, so every
//!   job against one MPS lands on the backend whose `StoreCache` already
//!   holds it. A `Busy` backend spills over to the next-ranked routable
//!   backend under a retry budget with capped-exponential backoff +
//!   jitter. The reply carries a *router-global* job id.
//! - `status`/`wait`/`cancel` map the global id back to its backend and
//!   forward; replies are rewritten to the global id. `wait` re-streams
//!   the backend's binary sample payload verbatim semantics.
//! - `list` fans out to routable backends and merges the views of jobs
//!   routed through this gateway, sorted by (submit time, id).
//! - `shutdown` drains: new submits are refused while every in-flight
//!   routed job is polled to a terminal state, then the final metrics are
//!   the reply — proof of drain, mirroring the single-server semantics.
//!
//! A prober thread pings each backend every `probe_interval_ms` and
//! drives the alive/degraded/down state that gates routing.

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::health::{failover_order, BackendHealth, HealthState};
use super::rendezvous;
use crate::config::{ComputePrecision, NetConfig, RouterConfig};
use crate::metrics::{keys, HistogramStats, Metrics};
use crate::trace::{self, Layer, Recorder};
use crate::net::frame::{self, Frame, FrameReader, FrameWriter};
use crate::net::server::{lame_duck_reject, reap_conns, reply_err, reply_ok};
use crate::net::push::PushShard;
use crate::net::Client;
use crate::service::{JobId, JobSpec, TpGroup, TpPeer};
use crate::telemetry::{self, http::MetricsHttp, prom::Exposition, TsRing};
use crate::util::backoff::Backoff;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Router-tier counters, folded into a [`Metrics`] snapshot (plus the
/// listener's own wire traffic under the shared `net_*` keys).
#[derive(Default)]
pub struct RouterStats {
    pub submits: AtomicU64,
    pub spillovers: AtomicU64,
    pub busy_rejects: AtomicU64,
    pub forward_errors: AtomicU64,
    pub forwards: AtomicU64,
    pub probes: AtomicU64,
    pub probe_failures: AtomicU64,
    pub dropped_jobs: AtomicU64,
    /// Store pushes proxied to a backend to a completed upload.
    pub pushes: AtomicU64,
    /// `push_begin` requests answered by backend dedup (no upload).
    pub push_dedups: AtomicU64,
    /// Proxied pushes that failed mid-stream (client saw typed `busy`).
    pub push_failures: AtomicU64,
    /// Tensor-parallel jobs placed across a shard group.
    pub tp_submits: AtomicU64,
    /// TP submits refused typed (unresolvable group, member down/draining).
    pub tp_rejects: AtomicU64,
    /// Proxied pushes that announced a shard identity and were recorded
    /// in the router's shard map (dedup-answered pushes included).
    pub shard_pushes: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    pub conns_accepted: AtomicU64,
    pub conns_active: AtomicUsize,
    pub rejects_conn: AtomicU64,
}

impl RouterStats {
    fn add_io(&self, reader: Option<(u64, u64)>, writer: Option<(u64, u64)>) {
        if let Some((b, f)) = reader {
            self.bytes_in.fetch_add(b, Ordering::Relaxed);
            self.frames_in.fetch_add(f, Ordering::Relaxed);
        }
        if let Some((b, f)) = writer {
            self.bytes_out.fetch_add(b, Ordering::Relaxed);
            self.frames_out.fetch_add(f, Ordering::Relaxed);
        }
    }

    /// Fold the counters into a [`Metrics`] snapshot.
    pub fn account(&self, m: &mut Metrics) {
        m.add(keys::ROUTER_SUBMITS, self.submits.load(Ordering::Relaxed));
        m.add(keys::ROUTER_SPILLOVERS, self.spillovers.load(Ordering::Relaxed));
        m.add(keys::ROUTER_BUSY_REJECTS, self.busy_rejects.load(Ordering::Relaxed));
        m.add(
            keys::ROUTER_FORWARD_ERRORS,
            self.forward_errors.load(Ordering::Relaxed),
        );
        m.add(keys::ROUTER_FORWARDS, self.forwards.load(Ordering::Relaxed));
        m.add(keys::ROUTER_PROBES, self.probes.load(Ordering::Relaxed));
        m.add(
            keys::ROUTER_PROBE_FAILURES,
            self.probe_failures.load(Ordering::Relaxed),
        );
        m.add(keys::ROUTER_DROPPED_JOBS, self.dropped_jobs.load(Ordering::Relaxed));
        m.add(keys::ROUTER_PUSHES, self.pushes.load(Ordering::Relaxed));
        m.add(
            keys::ROUTER_PUSH_DEDUPS,
            self.push_dedups.load(Ordering::Relaxed),
        );
        m.add(
            keys::ROUTER_PUSH_FAILURES,
            self.push_failures.load(Ordering::Relaxed),
        );
        m.add(keys::ROUTER_TP_SUBMITS, self.tp_submits.load(Ordering::Relaxed));
        m.add(keys::ROUTER_TP_REJECTS, self.tp_rejects.load(Ordering::Relaxed));
        m.add(
            keys::ROUTER_SHARD_PUSHES,
            self.shard_pushes.load(Ordering::Relaxed),
        );
        m.add(keys::NET_BYTES_IN, self.bytes_in.load(Ordering::Relaxed));
        m.add(keys::NET_BYTES_OUT, self.bytes_out.load(Ordering::Relaxed));
        m.add(keys::NET_FRAMES_IN, self.frames_in.load(Ordering::Relaxed));
        m.add(keys::NET_FRAMES_OUT, self.frames_out.load(Ordering::Relaxed));
        m.add(keys::NET_CONNS, self.conns_accepted.load(Ordering::Relaxed));
        m.add(keys::NET_REJECTS_CONN, self.rejects_conn.load(Ordering::Relaxed));
    }
}

/// Per-backend forwarding counters (exposed in the metrics JSON).
#[derive(Default)]
struct BackendCounters {
    /// Jobs placed here.
    submits: AtomicU64,
    /// `Busy` replies seen from this backend.
    busy: AtomicU64,
    /// Transport-level forward failures.
    errors: AtomicU64,
    /// Non-submit RPCs forwarded here.
    forwards: AtomicU64,
}

/// Where one routed job lives.
#[derive(Debug, Clone, Copy)]
struct RoutedJob {
    backend: usize,
    backend_id: JobId,
    /// A reservation is taken *before* the first forward attempt and
    /// only becomes placed once a backend accepted the job; the drain
    /// waits on reservations too, closing the submit/drain race.
    placed: bool,
    /// Seen terminal (done/failed/cancelled) — drain bookkeeping.
    terminal: bool,
}

struct RouteTable {
    next_id: JobId,
    by_global: BTreeMap<JobId, RoutedJob>,
    by_backend: BTreeMap<(usize, JobId), JobId>,
}

/// Per-backend telemetry held by the fleet poller: the last successfully
/// scraped metrics document plus a ring of samples derived from it. The
/// document is kept (stale) across scrape failures so the exposition and
/// `fastmps top` never flicker empty while a backend blips.
struct FleetBackend {
    ring: TsRing,
    doc: Mutex<Option<Json>>,
}

/// One registered shard of a sharded store: which backend holds it,
/// under what content key, and how many blob bytes it announced.
#[derive(Clone, Copy)]
struct ShardMember {
    backend: usize,
    key: u64,
    bytes: u64,
}

/// Everything the router knows about one `of`-way sharding of a full
/// store (keyed by the full store's manifest hash), learned from proxied
/// shard pushes. Rank `r`'s slot stays `None` until shard `r` is pushed.
struct ShardSet {
    of: usize,
    members: Vec<Option<ShardMember>>,
}

impl ShardSet {
    fn empty(of: usize) -> ShardSet {
        ShardSet {
            of,
            members: vec![None; of],
        }
    }

    /// Sum of announced shard bytes — the auto-TP size proxy for the
    /// full store (shards partition its site blobs).
    fn bytes(&self) -> u64 {
        self.members.iter().flatten().map(|m| m.bytes).sum()
    }

    fn complete(&self) -> bool {
        self.members.iter().all(|m| m.is_some())
    }
}

struct Shared {
    cfg: RouterConfig,
    net: NetConfig,
    backends: Vec<Arc<BackendHealth>>,
    counters: Vec<BackendCounters>,
    stats: RouterStats,
    /// Router-tier flight recorder: placement attempts, spillovers, and
    /// (via attached backend clients) per-forward RPC spans.
    rec: Arc<Recorder>,
    /// Backend-leg round-trip latency, folded from connection threads.
    net_rtt: Mutex<HistogramStats>,
    /// Router-side telemetry ring, sampled on the telemetry interval.
    ring: TsRing,
    /// Scraped backend telemetry, index-aligned with `backends`.
    fleet: Vec<FleetBackend>,
    table: Mutex<RouteTable>,
    /// Shard map: full-store manifest hash → where its shards live
    /// (`docs/TENSOR_PARALLEL.md` § Group lifecycle).
    shards: Mutex<BTreeMap<u64, ShardSet>>,
    /// Close connections and stop the accept/probe loops.
    stop: AtomicBool,
    /// Refuse new submits (drain in progress or completed).
    draining: AtomicBool,
    /// A client's `shutdown` op has drained; `run_until_shutdown` observes.
    shutdown_requested: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

/// Transport-level failure (socket, framing, garbled reply) vs an
/// application-level error relayed from a backend. Only the former says
/// anything about the backend's health.
fn is_transport_error(e: &Error) -> bool {
    matches!(e, Error::Io { .. } | Error::Format(_) | Error::Json { .. })
}

/// Rewrite the `id` field of a backend reply to the router-global id.
fn with_global_id(mut j: Json, gid: JobId) -> Json {
    if let Json::Obj(ref mut m) = j {
        m.insert("id".into(), Json::Num(gid as f64));
    }
    j
}

fn terminal_status(view: &Json) -> bool {
    matches!(
        view.get("status").and_then(|v| v.as_str()),
        Some("done") | Some("failed")
    )
}

impl Shared {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Reserve a global id for a submit in flight, unless draining. The
    /// reservation is checked and inserted under the table lock, so a
    /// drain that starts concurrently either refuses this submit or
    /// sees the reservation in its pending snapshot and waits for it to
    /// be placed or released — the job can never slip past the drain.
    fn reserve(&self) -> Option<JobId> {
        let mut t = self.table.lock().unwrap();
        if self.draining() {
            return None;
        }
        let gid = t.next_id;
        t.next_id += 1;
        t.by_global.insert(
            gid,
            RoutedJob {
                backend: 0,
                backend_id: 0,
                placed: false,
                terminal: false,
            },
        );
        Some(gid)
    }

    /// Resolve a reservation to the backend that accepted the job.
    fn place(&self, gid: JobId, backend: usize, backend_id: JobId) {
        let mut t = self.table.lock().unwrap();
        if let Some(r) = t.by_global.get_mut(&gid) {
            r.backend = backend;
            r.backend_id = backend_id;
            r.placed = true;
        }
        t.by_backend.insert((backend, backend_id), gid);
    }

    /// Drop a reservation whose submit was refused everywhere.
    fn release(&self, gid: JobId) {
        self.table.lock().unwrap().by_global.remove(&gid);
    }

    fn routed(&self, gid: JobId) -> Option<RoutedJob> {
        let t = self.table.lock().unwrap();
        t.by_global.get(&gid).copied().filter(|r| r.placed)
    }

    fn mark_terminal(&self, gid: JobId) {
        let mut t = self.table.lock().unwrap();
        if let Some(r) = t.by_global.get_mut(&gid) {
            r.terminal = true;
        }
    }

    /// Fold a drained backend-leg RTT histogram into the router-wide one.
    fn fold_rtt(&self, h: HistogramStats) {
        if h.count == 0 {
            return;
        }
        self.net_rtt.lock().unwrap().merge(&h);
    }

    /// A transport-level forward failure: health + counters in one place.
    fn note_forward_failure(&self, b: usize) {
        self.counters[b].errors.fetch_add(1, Ordering::Relaxed);
        self.stats.forward_errors.fetch_add(1, Ordering::Relaxed);
        self.backends[b].note_failure(self.cfg.degraded_after, self.cfg.down_after);
    }

    fn note_forward(&self, b: usize) {
        self.stats.forwards.fetch_add(1, Ordering::Relaxed);
        self.counters[b].forwards.fetch_add(1, Ordering::Relaxed);
    }

    /// Learn (or refresh) where one shard of a sharded store lives. A
    /// push announcing a *different* group width supersedes the whole
    /// set: the old sharding is no longer the one clients will name.
    fn record_shard(&self, s: &PushShard, backend: usize, key: u64, bytes: u64) {
        let mut map = self.shards.lock().unwrap();
        let set = map.entry(s.base).or_insert_with(|| ShardSet::empty(s.of));
        if set.of != s.of {
            *set = ShardSet::empty(s.of);
        }
        set.members[s.index] = Some(ShardMember { backend, key, bytes });
        self.stats.shard_pushes.fetch_add(1, Ordering::Relaxed);
    }

    /// Auto-TP: a keyed f32 submit whose store has a complete registered
    /// shard group bigger than `shard_budget_bytes` is upgraded to a TP
    /// request as if the client had passed `--tp of`. Only jobs that
    /// *explicitly* pin f32 compute are upgraded — silently changing a
    /// job's effective precision to make it shardable is not the
    /// router's call.
    fn auto_tp(&self, spec: &JobSpec) -> Option<TpGroup> {
        if self.cfg.shard_budget_bytes == 0 {
            return None;
        }
        if spec.compute != Some(ComputePrecision::F32) {
            return None;
        }
        let base = spec.key?;
        let map = self.shards.lock().unwrap();
        let set = map.get(&base)?;
        (set.complete() && set.bytes() > self.cfg.shard_budget_bytes).then(|| TpGroup {
            of: set.of,
            base,
            peers: Vec::new(),
        })
    }

    /// Resolve a TP *request* (empty peer list) against the shard map
    /// and the health gate. `Err` carries the typed refusal text: TP
    /// groups never spill over — a missing or unroutable member fails
    /// the submit instead of silently degrading to a partial group.
    fn resolve_tp(&self, req: &TpGroup) -> std::result::Result<(usize, u64, Vec<TpPeer>), String> {
        let map = self.shards.lock().unwrap();
        let Some(set) = map.get(&req.base) else {
            return Err(format!(
                "no shard group registered for store {:016x} (push its shards through this router first)",
                req.base
            ));
        };
        if set.of != req.of {
            return Err(format!(
                "store {:016x} is sharded {}-way, not {}-way",
                req.base, set.of, req.of
            ));
        }
        let mut members = Vec::with_capacity(set.of);
        for (rank, m) in set.members.iter().enumerate() {
            let Some(m) = m else {
                return Err(format!(
                    "shard {rank}/{} of store {:016x} was never pushed",
                    set.of, req.base
                ));
            };
            let h = &self.backends[m.backend];
            if !h.routable() {
                return Err(format!(
                    "TP group member {} (rank {rank}) is {}; tensor-parallel jobs fail typed instead of spilling over",
                    h.addr,
                    h.state().as_str()
                ));
            }
            members.push((m.backend, m.key));
        }
        let (leader, leader_key) = members[0];
        let peers = members[1..]
            .iter()
            .map(|(b, k)| TpPeer {
                addr: self.backends[*b].addr.clone(),
                key: *k,
            })
            .collect();
        Ok((leader, leader_key, peers))
    }

    /// One router-side telemetry sample: routing-table occupancy as the
    /// queue depth, the backend-leg RTT quantiles, and the listener's
    /// wire counters. Engine-side fields (steps, cache hits) stay at
    /// their defaults — those belong to the scraped backend samples.
    fn telemetry_sample(&self) -> telemetry::TsSample {
        let (rtt_p50, rtt_p99) = {
            let rtt = self.net_rtt.lock().unwrap();
            (rtt.quantile(0.5), rtt.quantile(0.99))
        };
        let (routed, in_flight) = {
            let t = self.table.lock().unwrap();
            let live = t.by_global.values().filter(|r| !r.terminal).count();
            (t.by_global.len() as u64, live as u64)
        };
        let dropped = self.stats.dropped_jobs.load(Ordering::Relaxed);
        telemetry::TsSample {
            unix_ms: telemetry::now_unix_ms(),
            queue_depth: in_flight,
            jobs_submitted: self.stats.submits.load(Ordering::Relaxed),
            jobs_completed: (routed - in_flight).saturating_sub(dropped),
            jobs_failed: dropped,
            net_bytes_in: self.stats.bytes_in.load(Ordering::Relaxed),
            net_bytes_out: self.stats.bytes_out.load(Ordering::Relaxed),
            rtt_p50,
            rtt_p99,
            ..Default::default()
        }
    }

    /// Full router metrics: aggregate counters, per-backend health +
    /// counters, and routing-table occupancy.
    fn metrics_json(&self) -> Json {
        let mut m = Metrics::new();
        self.stats.account(&mut m);
        {
            let (mut degraded, mut down) = (0u64, 0u64);
            for h in &self.backends {
                degraded += h.degraded_transitions.load(Ordering::Relaxed);
                down += h.down_transitions.load(Ordering::Relaxed);
            }
            m.add(keys::ROUTER_HEALTH_DEGRADED, degraded);
            m.add(keys::ROUTER_HEALTH_DOWN, down);
        }
        {
            let rtt = self.net_rtt.lock().unwrap();
            if rtt.count > 0 {
                m.hists.insert(keys::HIST_NET_RTT.to_string(), rtt.clone());
            }
        }
        let (routed, in_flight) = {
            let t = self.table.lock().unwrap();
            let live = t.by_global.values().filter(|r| !r.terminal).count();
            (t.by_global.len(), live)
        };
        let backends = Json::Arr(
            self.backends
                .iter()
                .enumerate()
                .map(|(i, h)| {
                    let c = &self.counters[i];
                    Json::obj(vec![
                        ("addr", Json::Str(h.addr.clone())),
                        ("state", Json::Str(h.state().as_str().into())),
                        (
                            "probes",
                            Json::Num(h.probes.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "probe_failures",
                            Json::Num(h.probe_failures.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "degraded_transitions",
                            Json::Num(h.degraded_transitions.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "down_transitions",
                            Json::Num(h.down_transitions.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "submits",
                            Json::Num(c.submits.load(Ordering::Relaxed) as f64),
                        ),
                        ("busy", Json::Num(c.busy.load(Ordering::Relaxed) as f64)),
                        ("errors", Json::Num(c.errors.load(Ordering::Relaxed) as f64)),
                        (
                            "forwards",
                            Json::Num(c.forwards.load(Ordering::Relaxed) as f64),
                        ),
                    ])
                })
                .collect(),
        );
        let (shard_groups, shard_groups_complete) = {
            let map = self.shards.lock().unwrap();
            let complete = map.values().filter(|s| s.complete()).count();
            (map.len(), complete)
        };
        Json::obj(vec![
            ("config", self.cfg.to_json()),
            ("run", m.to_json()),
            ("backends", backends),
            ("jobs_routed", Json::Num(routed as f64)),
            ("jobs_in_flight", Json::Num(in_flight as f64)),
            ("shard_groups", Json::Num(shard_groups as f64)),
            (
                "shard_groups_complete",
                Json::Num(shard_groups_complete as f64),
            ),
        ])
    }

    /// Stop admitting new jobs and poll every in-flight routed job to a
    /// terminal state (or give up at `cap` / after repeated backend
    /// errors, counting those as dropped — a clean drain drops zero).
    fn drain(&self, cap: Duration) {
        self.draining.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + cap;
        let mut clients: Vec<Option<Client>> = self.backends.iter().map(|_| None).collect();
        let mut err_streak: BTreeMap<JobId, u32> = BTreeMap::new();
        let mut delay = Duration::from_millis(2);
        loop {
            let pending: Vec<(JobId, RoutedJob)> = {
                let t = self.table.lock().unwrap();
                t.by_global
                    .iter()
                    .filter(|(_, r)| !r.terminal)
                    .map(|(g, r)| (*g, *r))
                    .collect()
            };
            if pending.is_empty() {
                return;
            }
            if Instant::now() >= deadline {
                for (gid, _) in &pending {
                    self.stats.dropped_jobs.fetch_add(1, Ordering::Relaxed);
                    self.mark_terminal(*gid);
                }
                return;
            }
            for (gid, r) in pending {
                if !r.placed {
                    // A submit is mid-flight on some connection thread;
                    // it will place or release the reservation shortly
                    // (bounded by its socket timeouts + retry budget).
                    continue;
                }
                let status = (|| -> Result<Json> {
                    if clients[r.backend].is_none() {
                        clients[r.backend] =
                            Some(Client::connect(&self.backends[r.backend].addr, &self.net)?);
                    }
                    clients[r.backend].as_mut().unwrap().status(r.backend_id)
                })();
                match status {
                    Ok(view) => {
                        err_streak.remove(&gid);
                        if terminal_status(&view) {
                            self.mark_terminal(gid);
                        }
                    }
                    Err(e) if e.is_busy() => {
                        // Backend at its connection limit right now:
                        // backpressure, not evidence about the job — the
                        // pool-rejected socket is a lame duck, re-dial
                        // and keep polling.
                        err_streak.remove(&gid);
                        clients[r.backend] = None;
                    }
                    Err(e) if !is_transport_error(&e) && e.to_string().contains("unknown job") => {
                        // The backend answered but no longer knows the
                        // job (terminal history evicted) — it finished.
                        err_streak.remove(&gid);
                        self.mark_terminal(gid);
                    }
                    Err(_) => {
                        clients[r.backend] = None;
                        let n = err_streak.entry(gid).or_insert(0);
                        *n += 1;
                        if *n >= 5 {
                            // Backend unreachable: beyond recovery from
                            // here — count the job dropped and move on.
                            self.stats.dropped_jobs.fetch_add(1, Ordering::Relaxed);
                            self.mark_terminal(gid);
                        }
                    }
                }
            }
            std::thread::sleep(delay);
            delay = (delay * 2).min(Duration::from_millis(50));
        }
    }
}

/// A running routing gateway. Dropping it stops and joins the router's
/// threads *without* draining — routed jobs keep running on their
/// backends; use [`Router::shutdown`] (or the wire `shutdown` op) for a
/// drain with proof of completion.
pub struct Router {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    probe: Option<JoinHandle<()>>,
    poller: Option<JoinHandle<()>>,
    exporter: Option<MetricsHttp>,
}

impl Router {
    /// Start routing on `net.addr` (port 0 = ephemeral) across
    /// `cfg.backends`.
    pub fn start(cfg: RouterConfig, net: NetConfig) -> Result<Router> {
        cfg.validate()?;
        net.validate()?;
        let listener =
            TcpListener::bind(&net.addr).map_err(|e| Error::io(format!("bind {}", net.addr), e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::io("local_addr", e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::io("set_nonblocking", e))?;
        let backends: Vec<Arc<BackendHealth>> = cfg
            .backends
            .iter()
            .map(|a| Arc::new(BackendHealth::new(a.clone())))
            .collect();
        let counters = cfg.backends.iter().map(|_| BackendCounters::default()).collect();
        let fleet = cfg
            .backends
            .iter()
            .map(|_| FleetBackend {
                ring: TsRing::new(telemetry::RING_CAPACITY),
                doc: Mutex::new(None),
            })
            .collect();
        let rec = Arc::new(Recorder::new(cfg.trace_buf));
        let shared = Arc::new(Shared {
            cfg,
            net,
            backends,
            counters,
            stats: RouterStats::default(),
            rec,
            net_rtt: Mutex::new(HistogramStats::new()),
            ring: TsRing::new(telemetry::RING_CAPACITY),
            fleet,
            table: Mutex::new(RouteTable {
                next_id: 1,
                by_global: BTreeMap::new(),
                by_backend: BTreeMap::new(),
            }),
            shards: Mutex::new(BTreeMap::new()),
            stop: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            shutdown_requested: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
        });
        // Exporter first: a bad --metrics-listen address aborts startup
        // cleanly, before any thread needs joining.
        let exporter = match shared.net.metrics_listen.clone() {
            Some(listen) => {
                let sh = shared.clone();
                let render: crate::telemetry::http::RenderFn =
                    Arc::new(move || render_fleet(&sh));
                Some(MetricsHttp::start(&listen, render)?)
            }
            None => None,
        };
        let probe = {
            let shared = shared.clone();
            std::thread::spawn(move || probe_loop(shared))
        };
        let poller = {
            let shared = shared.clone();
            std::thread::spawn(move || fleet_poll_loop(shared))
        };
        let accept = {
            let shared = shared.clone();
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        Ok(Router {
            shared,
            addr,
            accept: Some(accept),
            probe: Some(probe),
            poller: Some(poller),
            exporter,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The Prometheus exporter's bound address, when `metrics_listen` is
    /// configured (resolves port 0).
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.exporter.as_ref().map(|e| e.local_addr())
    }

    /// Current router metrics (aggregate + per-backend).
    pub fn metrics_json(&self) -> Json {
        self.shared.metrics_json()
    }

    /// Health snapshot, backend order as configured (for tests/ops).
    pub fn health(&self) -> Vec<(String, HealthState)> {
        self.shared
            .backends
            .iter()
            .map(|b| (b.addr.clone(), b.state()))
            .collect()
    }

    /// True once a client's `shutdown` op has drained the router.
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Block until a client requests shutdown or `max_secs` elapses.
    pub fn run_until_shutdown(&self, max_secs: Option<f64>) {
        let t0 = Instant::now();
        while !self.shutdown_requested() && !self.shared.stopping() {
            if let Some(max) = max_secs {
                if t0.elapsed().as_secs_f64() >= max {
                    return;
                }
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.probe.take() {
            let _ = h.join();
        }
        if let Some(h) = self.poller.take() {
            let _ = h.join();
        }
        if let Some(e) = self.exporter.as_mut() {
            e.shutdown();
        }
        let conns: Vec<JoinHandle<()>> = std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
    }

    /// Drain in-flight routed jobs, stop every thread, and return the
    /// final metrics.
    pub fn shutdown(mut self) -> Json {
        self.shared.drain(Duration::from_secs(self.shared.cfg.drain_cap_secs));
        self.stop_and_join();
        self.shared.metrics_json()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn probe_loop(shared: Arc<Shared>) {
    // Probes want to fail fast: tighten both timeouts toward the probe
    // period (the write timeout doubles as the client's dial deadline)
    // so one wedged or blackholed backend cannot stall the whole round.
    let probe_ms = shared.cfg.probe_interval_ms.max(50);
    let net = NetConfig {
        read_timeout_ms: shared.net.read_timeout_ms.min(probe_ms),
        write_timeout_ms: shared.net.write_timeout_ms.min(probe_ms.max(250)),
        ..shared.net.clone()
    };
    while !shared.stopping() {
        for h in &shared.backends {
            if shared.stopping() {
                return;
            }
            let ok = Client::connect(&h.addr, &net)
                .and_then(|mut c| c.ping())
                .is_ok();
            h.note_probe(ok, shared.cfg.degraded_after, shared.cfg.down_after);
            shared.stats.probes.fetch_add(1, Ordering::Relaxed);
            if !ok {
                shared.stats.probe_failures.fetch_add(1, Ordering::Relaxed);
            }
        }
        let deadline = Instant::now() + Duration::from_millis(shared.cfg.probe_interval_ms);
        loop {
            if shared.stopping() {
                return;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            std::thread::sleep(left.min(Duration::from_millis(10)));
        }
    }
}

/// Telemetry sweep: one router-side ring sample plus a `metrics` scrape
/// of every backend per telemetry interval. Mirrors `probe_loop`'s
/// timeout tightening so one wedged backend cannot stall the sweep — but
/// unlike the prober it never touches health state: a failed scrape just
/// keeps the backend's previous document (the prober owns liveness).
fn fleet_poll_loop(shared: Arc<Shared>) {
    let interval_ms = shared.net.telemetry_interval_ms.max(10);
    let net = NetConfig {
        read_timeout_ms: shared.net.read_timeout_ms.min(interval_ms.max(250)),
        write_timeout_ms: shared.net.write_timeout_ms.min(interval_ms.max(250)),
        ..shared.net.clone()
    };
    while !shared.stopping() {
        shared.ring.snapshot(shared.telemetry_sample());
        for (i, h) in shared.backends.iter().enumerate() {
            if shared.stopping() {
                return;
            }
            let doc = Client::connect(&h.addr, &net)
                .and_then(|mut c| c.metrics())
                .ok();
            if let Some(doc) = doc {
                let sample =
                    telemetry::TsSample::from_metrics_json(&doc, telemetry::now_unix_ms());
                shared.fleet[i].ring.snapshot(sample);
                *shared.fleet[i].doc.lock().unwrap() = Some(doc);
            }
        }
        let deadline = Instant::now() + Duration::from_millis(interval_ms);
        loop {
            if shared.stopping() {
                return;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            std::thread::sleep(left.min(Duration::from_millis(10)));
        }
    }
}

/// Render the fleet exposition: the router's own document unlabeled,
/// then per backend a health-state gauge, an info series carrying the
/// address, and the last scraped backend document with a
/// `backend="<index>"` label on every series.
fn render_fleet(shared: &Shared) -> String {
    let mut exp = Exposition::new();
    exp.add_metrics_json(&shared.metrics_json(), &[]);
    for (i, h) in shared.backends.iter().enumerate() {
        let idx = i.to_string();
        let labels: [(&str, &str); 1] = [("backend", idx.as_str())];
        exp.gauge(
            "router_backend_state",
            "Backend health as seen by the prober: 0 alive, 1 degraded, 2 down.",
            &labels,
            h.state() as u8 as f64,
        );
        exp.gauge(
            "router_backend_info",
            "Constant 1; the labels carry the backend address.",
            &[("backend", idx.as_str()), ("addr", h.addr.as_str())],
            1.0,
        );
        if let Some(doc) = shared.fleet[i].doc.lock().unwrap().as_ref() {
            exp.add_metrics_json(doc, &labels);
        }
    }
    exp.render()
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    while !shared.stopping() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                handle_accept(stream, &shared);
                reap_conns(&shared.conns);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn handle_accept(stream: TcpStream, shared: &Arc<Shared>) {
    let stats = &shared.stats;
    let prev = stats.conns_active.fetch_add(1, Ordering::SeqCst);
    if prev >= shared.net.max_conns {
        stats.conns_active.fetch_sub(1, Ordering::SeqCst);
        stats.rejects_conn.fetch_add(1, Ordering::Relaxed);
        lame_duck_reject(stream, shared.net.write_timeout_ms);
        return;
    }
    stats.conns_accepted.fetch_add(1, Ordering::Relaxed);
    let shared2 = shared.clone();
    let handle = std::thread::spawn(move || {
        connection(stream, &shared2);
        shared2.stats.conns_active.fetch_sub(1, Ordering::SeqCst);
    });
    shared.conns.lock().unwrap().push(handle);
}

/// Lazily-connected per-client-connection backend channels. Requests on
/// one client connection are sequential, so these need no locking; a
/// channel that errors is dropped and re-dialed on next use.
struct BackendConns {
    clients: Vec<Option<Client>>,
    /// RTT samples salvaged from dropped channels, pending a fold into
    /// the shared router histogram.
    rtt: HistogramStats,
}

impl BackendConns {
    fn new(n: usize) -> BackendConns {
        BackendConns {
            clients: (0..n).map(|_| None).collect(),
            rtt: HistogramStats::new(),
        }
    }

    fn client(&mut self, b: usize, shared: &Shared) -> Result<&mut Client> {
        if self.clients[b].is_none() {
            let mut c = Client::connect(&shared.backends[b].addr, &shared.net)?;
            // Forwarded RPCs show up as Client-layer spans in the
            // router's own timeline.
            c.set_recorder(shared.rec.clone());
            self.clients[b] = Some(c);
        }
        Ok(self.clients[b].as_mut().expect("just connected"))
    }

    fn drop_conn(&mut self, b: usize) {
        if let Some(mut c) = self.clients[b].take() {
            self.rtt.merge(&c.take_rtt());
        }
    }

    /// Drain every backend leg's RTT histogram (live and salvaged).
    fn take_rtt(&mut self) -> HistogramStats {
        let mut h = std::mem::replace(&mut self.rtt, HistogramStats::new());
        for c in self.clients.iter_mut().flatten() {
            h.merge(&c.take_rtt());
        }
        h
    }
}

/// One client connection: single-threaded, inline replies (the protocol
/// is strictly sequential per connection, so no writer thread is
/// needed — forwarding latency dominates anyway).
fn connection(stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(
        shared.net.read_timeout_ms.max(1),
    )));
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = write_half.set_write_timeout(Some(Duration::from_millis(
        shared.net.write_timeout_ms.max(1),
    )));
    let mut w = FrameWriter::new(BufWriter::new(write_half));
    if w.write_preamble().is_err() {
        return;
    }
    let mut reader = FrameReader::new(BufReader::new(stream), shared.net.max_frame_bytes);
    let mut conns = BackendConns::new(shared.backends.len());
    let outcome = (|| -> Result<()> {
        reader.read_preamble()?;
        loop {
            if shared.stopping() {
                return Ok(());
            }
            let msg = match reader.read_frame_idle()? {
                None => continue, // idle tick: re-check the stop flag
                Some(Frame::Payload(_) | Frame::Chunk(_) | Frame::Tp(_)) => {
                    return Err(Error::format(
                        "net wire: unexpected binary frame from client",
                    ));
                }
                Some(Frame::Ctrl(msg)) => msg,
            };
            shared.stats.add_io(Some(reader.drain_counters()), None);
            let more = if msg.get("op").and_then(|v| v.as_str()) == Some("push_begin") {
                // The push owns the reader until push_end; drive the
                // relay from here, where the reader is in scope.
                handle_push_proxy(&msg, &mut reader, &mut w, &mut conns, shared)?;
                true
            } else {
                handle_op(&msg, &mut w, &mut conns, shared)?
            };
            shared.stats.add_io(None, Some(w.drain_counters()));
            if !more {
                return Ok(());
            }
        }
    })();
    shared.fold_rtt(conns.take_rtt());
    shared.stats.add_io(Some(reader.drain_counters()), None);
    if let Err(e) = outcome {
        if !frame::is_timeout(&e) {
            let _ = w.write_ctrl(&reply_err("error", &e));
        }
    }
    shared.stats.add_io(None, Some(w.drain_counters()));
}

fn req_job_id(msg: &Json) -> Result<JobId> {
    msg.req("id")?
        .as_f64()
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .map(|v| v as JobId)
        .ok_or_else(|| Error::format("net: 'id' is not a job id"))
}

/// Execute one control op; `Ok(false)` closes the connection.
fn handle_op(
    msg: &Json,
    w: &mut FrameWriter<BufWriter<TcpStream>>,
    conns: &mut BackendConns,
    shared: &Arc<Shared>,
) -> Result<bool> {
    let op = msg.get("op").and_then(|v| v.as_str()).unwrap_or("");
    match op {
        "ping" => w.write_ctrl(&reply_ok("pong", vec![]))?,
        "submit" => handle_submit(msg, w, conns, shared)?,
        "status" => {
            let gid = req_job_id(msg)?;
            match shared.routed(gid) {
                None => w.write_ctrl(&reply_err("error", format!("unknown job {gid}")))?,
                Some(r) => {
                    shared.note_forward(r.backend);
                    let view = conns
                        .client(r.backend, shared)
                        .and_then(|c| c.status(r.backend_id));
                    match view {
                        Ok(view) => {
                            shared.backends[r.backend].note_ok();
                            if terminal_status(&view) {
                                shared.mark_terminal(gid);
                            }
                            w.write_ctrl(&reply_ok(
                                "status",
                                vec![("job", with_global_id(view, gid))],
                            ))?;
                        }
                        Err(e) => relay_error(w, conns, shared, r.backend, e)?,
                    }
                }
            }
        }
        "wait" => {
            let gid = req_job_id(msg)?;
            let timeout_ms = msg
                .get("timeout_ms")
                .and_then(|v| v.as_f64())
                .filter(|t| *t >= 0.0)
                .unwrap_or(60_000.0)
                .min(600_000.0);
            match shared.routed(gid) {
                None => w.write_ctrl(&reply_err("error", format!("unknown job {gid}")))?,
                Some(r) => {
                    shared.note_forward(r.backend);
                    let timeout = Duration::from_millis(timeout_ms as u64);
                    let outcome = conns
                        .client(r.backend, shared)
                        .and_then(|c| c.wait(r.backend_id, timeout));
                    match outcome {
                        Ok(Some(res)) => {
                            shared.backends[r.backend].note_ok();
                            shared.mark_terminal(gid);
                            let payload = res.sink.as_ref().map(frame::pack_sink);
                            w.write_ctrl(&reply_ok(
                                "result",
                                vec![
                                    ("result", with_global_id(res.result, gid)),
                                    ("payload", Json::Bool(payload.is_some())),
                                ],
                            ))?;
                            if let Some(p) = payload {
                                w.write_payload(&p)?;
                            }
                        }
                        Ok(None) => {
                            // Still running at the client's deadline:
                            // relay the live status, like the server does.
                            let view = conns
                                .client(r.backend, shared)
                                .and_then(|c| c.status(r.backend_id));
                            match view {
                                Ok(view) => w.write_ctrl(&reply_ok(
                                    "status",
                                    vec![("job", with_global_id(view, gid))],
                                ))?,
                                Err(e) => relay_error(w, conns, shared, r.backend, e)?,
                            }
                        }
                        Err(e) => relay_error(w, conns, shared, r.backend, e)?,
                    }
                }
            }
        }
        "cancel" => {
            let gid = req_job_id(msg)?;
            match shared.routed(gid) {
                None => w.write_ctrl(&reply_err("error", format!("unknown job {gid}")))?,
                Some(r) => {
                    shared.note_forward(r.backend);
                    let outcome = conns
                        .client(r.backend, shared)
                        .and_then(|c| c.cancel(r.backend_id));
                    match outcome {
                        Ok(()) => {
                            shared.backends[r.backend].note_ok();
                            shared.mark_terminal(gid);
                            w.write_ctrl(&reply_ok(
                                "cancelled",
                                vec![("id", Json::Num(gid as f64))],
                            ))?;
                        }
                        Err(e) => relay_error(w, conns, shared, r.backend, e)?,
                    }
                }
            }
        }
        "list" => {
            let map: BTreeMap<(usize, JobId), JobId> =
                shared.table.lock().unwrap().by_backend.clone();
            let mut entries: Vec<(f64, JobId, Json)> = Vec::new();
            for b in 0..shared.backends.len() {
                if !shared.backends[b].routable() {
                    continue;
                }
                shared.note_forward(b);
                let listed = conns.client(b, shared).and_then(|c| c.list());
                match listed {
                    Ok(jobs) => {
                        shared.backends[b].note_ok();
                        for j in jobs.as_arr().unwrap_or(&[]) {
                            let Some(bid) =
                                j.get("id").and_then(|v| v.as_f64()).map(|v| v as JobId)
                            else {
                                continue;
                            };
                            // Jobs not routed through this gateway (e.g.
                            // submitted to a backend directly) stay out
                            // of the merged view — their ids are not ours
                            // to expose.
                            let Some(&gid) = map.get(&(b, bid)) else {
                                continue;
                            };
                            let t = j
                                .get("submitted_unix")
                                .and_then(|v| v.as_f64())
                                .unwrap_or(0.0);
                            entries.push((t, gid, with_global_id(j.clone(), gid)));
                        }
                    }
                    Err(e) => {
                        if is_transport_error(&e) {
                            shared.note_forward_failure(b);
                            conns.drop_conn(b);
                        }
                        // A partial merge beats no reply: skip this
                        // backend and report what the rest returned.
                    }
                }
            }
            entries.sort_by(|a, b| {
                a.0.partial_cmp(&b.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.1.cmp(&b.1))
            });
            let jobs = Json::Arr(entries.into_iter().map(|(_, _, j)| j).collect());
            w.write_ctrl(&reply_ok("jobs", vec![("jobs", jobs)]))?;
        }
        "metrics" => {
            // Fold this connection's backend-leg RTT first so the
            // snapshot includes the forwards that led up to the ask.
            shared.fold_rtt(conns.take_rtt());
            w.write_ctrl(&reply_ok("metrics", vec![("metrics", shared.metrics_json())]))?;
        }
        "telemetry" => {
            let backends = Json::Arr(
                shared
                    .backends
                    .iter()
                    .enumerate()
                    .map(|(i, h)| {
                        Json::obj(vec![
                            ("backend", Json::Num(i as f64)),
                            ("addr", Json::Str(h.addr.clone())),
                            ("state", Json::Str(h.state().as_str().into())),
                            ("samples", shared.fleet[i].ring.to_json()),
                        ])
                    })
                    .collect(),
            );
            w.write_ctrl(&reply_ok(
                "telemetry",
                vec![
                    (
                        "interval_ms",
                        Json::Num(shared.net.telemetry_interval_ms as f64),
                    ),
                    ("samples", shared.ring.to_json()),
                    ("backends", backends),
                ],
            ))?;
        }
        "trace" => handle_trace(msg, w, conns, shared)?,
        "shutdown" => {
            shared.fold_rtt(conns.take_rtt());
            shared.drain(Duration::from_secs(shared.cfg.drain_cap_secs));
            // Flag before the reply is written: a client that has seen
            // the reply must never observe shutdown_requested() == false.
            shared.shutdown_requested.store(true, Ordering::SeqCst);
            w.write_ctrl(&reply_ok(
                "shutdown",
                vec![("metrics", shared.metrics_json())],
            ))?;
            return Ok(false);
        }
        other => w.write_ctrl(&reply_err("error", format!("unknown op '{other}'")))?,
    }
    Ok(true)
}

/// The `trace` op, router edition: the router's own placement events
/// stitched with the owning backend's timeline, backend-local job ids
/// rewritten to the router-global one. A lost backend degrades to the
/// router-side half of the story rather than an error — a partial
/// timeline still answers "where did the time go before the loss".
fn handle_trace(
    msg: &Json,
    w: &mut FrameWriter<BufWriter<TcpStream>>,
    conns: &mut BackendConns,
    shared: &Arc<Shared>,
) -> Result<()> {
    let gid = msg
        .get("id")
        .and_then(|v| v.as_f64())
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .map(|v| v as JobId)
        .unwrap_or(0);
    let trace_req = msg
        .get("trace")
        .and_then(|v| v.as_str())
        .and_then(trace::parse_trace_id)
        .unwrap_or(0);
    let mut own = shared.rec.events_for(gid, trace_req);
    // When only a job id was given, the router's own events resolve the
    // trace id — that is what lets the backend fetch pull in spans
    // recorded before the backend assigned its local job id.
    let trace_id = if trace_req != 0 {
        trace_req
    } else {
        own.iter().map(|e| e.trace).find(|t| *t != 0).unwrap_or(0)
    };
    if trace_req == 0 && trace_id != 0 {
        // Re-query with the resolved id: the forwarding-leg client spans
        // predate the reply that names the job, so they are trace-keyed
        // only and a pure by-job scan would miss them.
        own = shared.rec.events_for(gid, trace_id);
    }
    let mut events: Vec<Json> = match shared.rec.events_json(&own) {
        Json::Arr(v) => v,
        _ => Vec::new(),
    };
    if let Some(r) = shared.routed(gid) {
        shared.note_forward(r.backend);
        let fetched = conns
            .client(r.backend, shared)
            .and_then(|c| c.trace_events(r.backend_id, trace_id));
        match fetched {
            Ok(reply) => {
                shared.backends[r.backend].note_ok();
                let backend_bid = r.backend_id as f64;
                for e in reply.get("events").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                    let mut e = e.clone();
                    if let Json::Obj(m) = &mut e {
                        if m.get("job").and_then(|v| v.as_f64()) == Some(backend_bid) {
                            m.insert("job".into(), Json::Num(gid as f64));
                        }
                    }
                    events.push(e);
                }
            }
            Err(e) => {
                if is_transport_error(&e) {
                    shared.note_forward_failure(r.backend);
                    conns.drop_conn(r.backend);
                }
            }
        }
    }
    let events = trace::merge_events(events);
    w.write_ctrl(&reply_ok(
        "trace",
        vec![
            ("job", Json::Num(gid as f64)),
            (
                "trace",
                if trace_id != 0 {
                    Json::Str(format!("{trace_id:016x}"))
                } else {
                    Json::Null
                },
            ),
            ("events", Json::Arr(events)),
            ("dropped", Json::Num(shared.rec.dropped() as f64)),
            ("trace_buf", Json::Num(shared.rec.capacity() as f64)),
        ],
    ))
}

/// Relay a forward failure to the client, updating backend health when
/// the failure was transport-level.
fn relay_error(
    w: &mut FrameWriter<BufWriter<TcpStream>>,
    conns: &mut BackendConns,
    shared: &Shared,
    b: usize,
    e: Error,
) -> Result<()> {
    if e.is_busy() {
        // Backend-side backpressure stays *typed* through the router so
        // the client's busy handling (backoff + retry) still engages;
        // the pool-rejected channel is a lame duck, so re-dial next use.
        conns.drop_conn(b);
        w.write_ctrl(&reply_err("busy", e))
    } else if is_transport_error(&e) {
        shared.note_forward_failure(b);
        conns.drop_conn(b);
        w.write_ctrl(&reply_err(
            "error",
            format!("backend {}: {e}", shared.backends[b].addr),
        ))
    } else {
        // Application-level error from the backend ("server: …"): relay
        // verbatim — it says nothing about the backend's health.
        w.write_ctrl(&reply_err("error", e))
    }
}

/// Outcome of the spillover placement loop.
enum Placement {
    Placed {
        backend: usize,
        backend_id: JobId,
        spilled: bool,
    },
    /// Retry budget exhausted (or no routable backends) — typed `busy`.
    Saturated(&'static str),
    /// Terminal application-level rejection (bad job shape, over-limit,
    /// backend draining): retrying elsewhere would duplicate nothing
    /// but the refusal.
    Refused(Error),
}

/// A backend's synchronous "this store was never pushed here" refusal
/// (see `Service::submit`). Not terminal for placement: a keyed job's
/// store may live on another backend when health churn or spillover
/// shifted the rendezvous order since the push.
fn is_missing_store_error(e: &Error) -> bool {
    !is_transport_error(e) && e.to_string().contains("unknown store key")
}

/// Rendezvous placement with `Busy`-aware spillover (see module docs).
/// Infallible on the client socket by design: the caller holds a table
/// reservation, and keeping all `?` exits out of this loop guarantees
/// the reservation is always placed or released.
fn place_with_spillover(
    spec: &JobSpec,
    conns: &mut BackendConns,
    shared: &Arc<Shared>,
    gid: JobId,
    trace_id: u64,
) -> Placement {
    let key = spec.store_key();
    let addrs: Vec<&str> = shared.backends.iter().map(|b| b.addr.as_str()).collect();
    let first_choice = rendezvous::rank(key, &addrs)[0];
    let mut backoff = Backoff::new(
        shared.cfg.backoff_base_ms,
        shared.cfg.backoff_cap_ms,
        shared.cfg.jitter_ms,
        shared.cfg.seed ^ key,
    );
    let mut budget = shared.cfg.retry_budget;
    let mut saw_busy = false;
    let mut last_missing: Option<Error> = None;
    loop {
        let order = failover_order(key, &shared.backends);
        if order.is_empty() {
            return Placement::Saturated("no routable backends");
        }
        let order_len = order.len();
        let full_fleet = order_len == shared.backends.len();
        let mut pass_attempts = 0usize;
        let mut pass_missing = 0usize;
        for b in order {
            if budget == 0 {
                break;
            }
            budget -= 1;
            pass_attempts += 1;
            // Placement breadcrumbs (arg = 1-based backend index, so the
            // first backend is distinguishable from "no arg").
            shared
                .rec
                .instant(Layer::Router, "attempt", gid, trace_id, b as u64 + 1);
            let outcome = conns.client(b, shared).and_then(|c| c.submit(spec));
            match outcome {
                Ok(bid) => {
                    shared.backends[b].note_ok();
                    shared.counters[b].submits.fetch_add(1, Ordering::Relaxed);
                    if b != first_choice {
                        shared
                            .rec
                            .instant(Layer::Router, "spillover", gid, trace_id, b as u64 + 1);
                    }
                    return Placement::Placed {
                        backend: b,
                        backend_id: bid,
                        spilled: b != first_choice,
                    };
                }
                Err(e) if e.is_busy() => {
                    // A busy backend is healthy — spill to the next rank.
                    saw_busy = true;
                    shared.counters[b].busy.fetch_add(1, Ordering::Relaxed);
                    shared
                        .rec
                        .instant(Layer::Router, "busy", gid, trace_id, b as u64 + 1);
                }
                Err(e) if is_transport_error(&e) => {
                    shared.note_forward_failure(b);
                    conns.drop_conn(b);
                }
                Err(e) if is_missing_store_error(&e) => {
                    // The pushed store lives on some other backend; keep
                    // walking the failover order.
                    last_missing = Some(e);
                    pass_missing += 1;
                }
                Err(e) => return Placement::Refused(e),
            }
        }
        if full_fleet && !saw_busy && pass_attempts == order_len && pass_missing == pass_attempts {
            // An untruncated pass over the ENTIRE fleet in which every
            // backend answered "unknown store key", with no busy or
            // unreachable backend seen at any point: the store simply is
            // not in the fleet. Terminal — retry cannot conjure it. Any
            // weaker condition (budget-truncated pass, excluded backends,
            // an earlier busy) falls through to the typed-busy paths
            // below, because the key's holder may just be busy or down.
            return Placement::Refused(last_missing.expect("missing > 0"));
        }
        if budget == 0 {
            if saw_busy {
                // The holder of the store may merely be busy: typed busy
                // so the client backs off and retries.
                return Placement::Saturated("all backends busy (back off and retry)");
            }
            return match last_missing {
                // Every backend in the full fleet lacks the key —
                // retrying will not help until the store is pushed again.
                Some(e) if full_fleet => Placement::Refused(e),
                // Some backends were excluded (down): the key's holder
                // may be among them — retryable.
                Some(_) => Placement::Saturated(
                    "store key not on any reachable backend (holder may be down; retry)",
                ),
                None => Placement::Saturated("no backend accepted the job"),
            };
        }
        // Between spillover cycles: capped exponential backoff + jitter.
        std::thread::sleep(backoff.next_delay());
    }
}

/// Proxy one store push to the rendezvous-chosen backend (see
/// `docs/PROTOCOL.md` § Chunked store push, routing).
///
/// The `push_begin` message already carries the content key, so placement
/// needs no filesystem — the whole point of push. Delivery of
/// `push_begin` fails over freely (nothing is committed yet): the first
/// reachable backend in affinity order wins. Once chunks are streaming,
/// the router holds no buffer to replay from, so a lost backend aborts
/// the relay *cleanly*: the client's remaining frames are drained (the
/// framing stays in sync), the failure is counted against the backend's
/// health, and the client gets a typed `busy` — its retry lands on the
/// next-ranked backend because this one is now degraded/down.
fn handle_push_proxy(
    msg: &Json,
    reader: &mut FrameReader<BufReader<TcpStream>>,
    w: &mut FrameWriter<BufWriter<TcpStream>>,
    conns: &mut BackendConns,
    shared: &Arc<Shared>,
) -> Result<()> {
    let Some(key) = msg
        .get("key")
        .and_then(|v| v.as_str())
        .and_then(|s| u64::from_str_radix(s, 16).ok())
    else {
        w.write_ctrl(&reply_err("error", "push_begin without a hex 'key'"))?;
        return Ok(());
    };
    if shared.draining() {
        w.write_ctrl(&reply_err("error", "router shutting down (draining)"))?;
        return Ok(());
    }
    // Shard identity, when announced: recorded in the shard map once the
    // push lands (the backend validates it against the staged manifest,
    // so a garbled announce never reaches the map — the begin fails).
    let shard = PushShard::parse(msg).ok().flatten();
    let announced_bytes = msg
        .get("total_bytes")
        .and_then(|v| v.as_f64())
        .filter(|v| *v >= 0.0)
        .map(|v| v as u64)
        .unwrap_or(0);

    // Deliver push_begin along the affinity order; failover is free here.
    let mut chosen: Option<(usize, Json)> = None;
    for b in failover_order(key, &shared.backends) {
        match conns.client(b, shared).and_then(|c| c.rpc_raw(msg)) {
            Ok(reply) => {
                chosen = Some((b, reply));
                break;
            }
            Err(_) => {
                shared.note_forward_failure(b);
                conns.drop_conn(b);
            }
        }
    }
    let Some((b, ready)) = chosen else {
        shared.stats.busy_rejects.fetch_add(1, Ordering::Relaxed);
        w.write_ctrl(&reply_err("busy", "no routable backends for push"))?;
        return Ok(());
    };
    shared.note_forward(b);
    let ok = ready.get("ok").and_then(|v| v.as_bool()) == Some(true);
    let dedup = ready.get("dedup").and_then(|v| v.as_bool()) == Some(true);
    w.write_ctrl(&ready)?;
    if !ok || dedup {
        // Rejection or dedup: the client sends no chunks; verdict relayed
        // verbatim, stream in sync.
        if ok {
            shared.backends[b].note_ok();
            shared.stats.push_dedups.fetch_add(1, Ordering::Relaxed);
            if let Some(s) = &shard {
                // Dedup still teaches placement: the shard provably
                // lives on this backend.
                shared.record_shard(s, b, key, announced_bytes);
            }
        }
        return Ok(());
    }
    shared.backends[b].note_ok();
    // Bound for the failure drain: the client announced its chunk count,
    // so a drain consuming more than that is a protocol violation, not
    // patience worth having.
    let announced_chunks = msg
        .get("chunks")
        .and_then(|v| v.as_f64())
        .filter(|v| *v >= 1.0)
        .map(|v| v as u64)
        .unwrap_or(u64::MAX);

    let lose_backend = |conns: &mut BackendConns,
                        w: &mut FrameWriter<BufWriter<TcpStream>>,
                        reader: &mut FrameReader<BufReader<TcpStream>>,
                        drain_chunks: Option<u64>|
     -> Result<()> {
        shared.note_forward_failure(b);
        conns.drop_conn(b);
        shared.stats.push_failures.fetch_add(1, Ordering::Relaxed);
        if let Some(remaining) = drain_chunks {
            drain_push_stream(reader, &shared.net, remaining)?;
        }
        w.write_ctrl(&reply_err(
            "busy",
            format!(
                "backend {} lost mid-push; retry (placement will re-route)",
                shared.backends[b].addr
            ),
        ))
    };

    let stall_cap = shared.net.push_stall_cap();
    let mut last_frame = Instant::now();
    let mut forwarded = 0u64;
    loop {
        if shared.stopping() {
            return Err(Error::other("router stopping during push"));
        }
        let frame = match reader.read_frame_idle()? {
            Some(f) => f,
            None => {
                if last_frame.elapsed() > stall_cap {
                    return Err(Error::other("push relay stalled"));
                }
                continue;
            }
        };
        last_frame = Instant::now();
        match frame {
            Frame::Chunk(packed) => {
                if forwarded >= announced_chunks {
                    return Err(Error::format("more push chunks than announced"));
                }
                forwarded += 1;
                let fwd = conns.client(b, shared).and_then(|c| c.forward_chunk(&packed));
                if fwd.is_err() {
                    let left = announced_chunks.saturating_sub(forwarded);
                    return lose_backend(conns, w, reader, Some(left));
                }
            }
            Frame::Ctrl(m) if m.get("op").and_then(|v| v.as_str()) == Some("push_end") => {
                // The backend's finalize can outlast one RPC deadline —
                // widen the relay leg exactly as a direct client does.
                let end_ms = NetConfig::push_end_timeout_ms(shared.net.read_timeout_ms);
                let reply = conns
                    .client(b, shared)
                    .and_then(|c| c.rpc_raw_deadline(&m, end_ms));
                let reply = match reply {
                    Ok(r) => r,
                    Err(_) => return lose_backend(conns, w, reader, None),
                };
                if reply.get("ok").and_then(|v| v.as_bool()) == Some(true) {
                    shared.backends[b].note_ok();
                    shared.stats.pushes.fetch_add(1, Ordering::Relaxed);
                    if let Some(s) = &shard {
                        shared.record_shard(s, b, key, announced_bytes);
                    }
                }
                return w.write_ctrl(&reply);
            }
            Frame::Ctrl(_) => {
                return Err(Error::format(
                    "net wire: unexpected control frame during push relay",
                ));
            }
            Frame::Payload(_) | Frame::Tp(_) => {
                return Err(Error::format(
                    "net wire: unexpected payload/TP frame during push relay",
                ));
            }
        }
    }
}

/// Consume the client's remaining push frames after the backend is gone,
/// so the connection's framing stays in sync for the rejection reply.
/// Progress-bounded, not wall-clock-bounded: frames may keep arriving for
/// as long as a quota-sized push legitimately takes, but at most
/// `max_chunks` of them — and any gap beyond the shared stall cap aborts.
fn drain_push_stream(
    reader: &mut FrameReader<BufReader<TcpStream>>,
    net: &NetConfig,
    max_chunks: u64,
) -> Result<()> {
    let stall_cap = net.push_stall_cap();
    let mut last_frame = Instant::now();
    let mut seen = 0u64;
    loop {
        match reader.read_frame_idle()? {
            None => {
                if last_frame.elapsed() > stall_cap {
                    return Err(Error::other("push drain stalled"));
                }
            }
            Some(Frame::Chunk(_)) => {
                seen += 1;
                if seen > max_chunks {
                    return Err(Error::format("more push chunks than announced"));
                }
                last_frame = Instant::now();
            }
            Some(Frame::Ctrl(m))
                if m.get("op").and_then(|v| v.as_str()) == Some("push_end") =>
            {
                return Ok(());
            }
            Some(_) => {
                return Err(Error::format(
                    "net wire: unexpected frame during push drain",
                ));
            }
        }
    }
}

fn handle_submit(
    msg: &Json,
    w: &mut FrameWriter<BufWriter<TcpStream>>,
    conns: &mut BackendConns,
    shared: &Arc<Shared>,
) -> Result<()> {
    let mut spec = JobSpec::from_json(msg.req("job")?)?;
    let trace_id = spec.trace.unwrap_or(0);
    // Tensor-parallel path: an explicit `tp` request, or a keyed f32 job
    // whose store's registered shard group exceeds `shard_budget_bytes`.
    if spec.tp.is_none() {
        spec.tp = shared.auto_tp(&spec);
    }
    if spec.tp.is_some() {
        return handle_submit_tp(spec, w, conns, shared, trace_id);
    }
    let Some(gid) = shared.reserve() else {
        w.write_ctrl(&reply_err("error", "router shutting down (draining)"))?;
        return Ok(());
    };
    shared.rec.begin(Layer::Router, "place", gid, trace_id);
    let placement = place_with_spillover(&spec, conns, shared, gid, trace_id);
    shared.rec.end(Layer::Router, "place", gid, trace_id);
    match placement {
        Placement::Placed {
            backend,
            backend_id,
            spilled,
        } => {
            shared.place(gid, backend, backend_id);
            shared.stats.submits.fetch_add(1, Ordering::Relaxed);
            if spilled {
                shared.stats.spillovers.fetch_add(1, Ordering::Relaxed);
            }
            w.write_ctrl(&reply_ok("submitted", vec![("id", Json::Num(gid as f64))]))
        }
        Placement::Saturated(m) => {
            shared.release(gid);
            shared.stats.busy_rejects.fetch_add(1, Ordering::Relaxed);
            w.write_ctrl(&reply_err("busy", m))
        }
        Placement::Refused(e) => {
            shared.release(gid);
            w.write_ctrl(&reply_err("error", e))
        }
    }
}

/// Place a tensor-parallel job (`docs/TENSOR_PARALLEL.md` § Group
/// lifecycle). Unlike the serial path there is no spillover and no
/// retry loop: the group is pinned to the backends holding its shards,
/// so every failure mode is either typed backpressure (`busy`, leader
/// at capacity — the client's normal retry re-resolves the group) or a
/// typed refusal that names the member and the reason.
fn handle_submit_tp(
    mut spec: JobSpec,
    w: &mut FrameWriter<BufWriter<TcpStream>>,
    conns: &mut BackendConns,
    shared: &Arc<Shared>,
    trace_id: u64,
) -> Result<()> {
    let req = spec.tp.clone().expect("caller checked tp");
    let refuse = |w: &mut FrameWriter<BufWriter<TcpStream>>, text: String| -> Result<()> {
        shared.stats.tp_rejects.fetch_add(1, Ordering::Relaxed);
        w.write_ctrl(&reply_err("error", text))
    };
    // Placement is the router's to make: a client-supplied peer list
    // would bypass both the shard map and the health gate.
    if !req.peers.is_empty() {
        return refuse(
            w,
            "tp submit carries resolved peers; send a request (empty peer list) and let the router place the group".into(),
        );
    }
    let (leader, leader_key, peers) = match shared.resolve_tp(&req) {
        Ok(v) => v,
        Err(text) => return refuse(w, text),
    };
    let Some(gid) = shared.reserve() else {
        w.write_ctrl(&reply_err("error", "router shutting down (draining)"))?;
        return Ok(());
    };
    spec.key = Some(leader_key);
    spec.tp = Some(TpGroup {
        of: req.of,
        base: req.base,
        peers,
    });
    shared.rec.begin(Layer::Router, "place_tp", gid, trace_id);
    shared
        .rec
        .instant(Layer::Router, "attempt", gid, trace_id, leader as u64 + 1);
    let outcome = conns.client(leader, shared).and_then(|c| c.submit(&spec));
    shared.rec.end(Layer::Router, "place_tp", gid, trace_id);
    match outcome {
        Ok(bid) => {
            shared.backends[leader].note_ok();
            shared.counters[leader].submits.fetch_add(1, Ordering::Relaxed);
            shared.place(gid, leader, bid);
            shared.stats.submits.fetch_add(1, Ordering::Relaxed);
            shared.stats.tp_submits.fetch_add(1, Ordering::Relaxed);
            w.write_ctrl(&reply_ok(
                "submitted",
                vec![
                    ("id", Json::Num(gid as f64)),
                    ("tp", Json::Num(req.of as f64)),
                ],
            ))
        }
        Err(e) if e.is_busy() => {
            shared.counters[leader].busy.fetch_add(1, Ordering::Relaxed);
            shared.release(gid);
            shared.stats.busy_rejects.fetch_add(1, Ordering::Relaxed);
            w.write_ctrl(&reply_err(
                "busy",
                format!(
                    "TP leader {} is busy; back off and retry (the group cannot spill over)",
                    shared.backends[leader].addr
                ),
            ))
        }
        Err(e) if is_transport_error(&e) => {
            shared.note_forward_failure(leader);
            conns.drop_conn(leader);
            shared.release(gid);
            refuse(
                w,
                format!(
                    "TP leader {} unreachable: {e}",
                    shared.backends[leader].addr
                ),
            )
        }
        Err(e) => {
            // Application-level refusal from the leader (f32-only,
            // shard mismatch, backend draining): relayed verbatim.
            shared.release(gid);
            refuse(w, e.to_string())
        }
    }
}
