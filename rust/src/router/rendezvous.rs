//! Rendezvous (highest-random-weight) hashing — the placement function
//! of the routing tier.
//!
//! For a routing key `k` (a store's manifest hash) and a backend address
//! `b`, the weight is a 64-bit mix of `hash(b)` and `k`; jobs go to the
//! highest-weight backend. The property that makes HRW the right tool
//! here (vs. mod-N or consistent-hash rings): when a backend joins or
//! leaves, the *only* keys that move are the ones whose top choice was
//! the departed backend (≈ 1/N of them) — every other store keeps its
//! warm `StoreCache` entry on the same backend. The full descending
//! ranking doubles as the failover order: spillover walks down the same
//! list every router instance computes, so a fleet of routers agrees on
//! placement without coordination.

use crate::util::backoff::mix64;
use crate::util::fnv1a;

/// HRW weight of `backend` for `key`. Deterministic across processes —
/// no per-run state enters the hash.
pub fn weight(key: u64, backend: &str) -> u64 {
    mix64(key ^ fnv1a(backend.as_bytes()).rotate_left(32))
}

/// Backend indices ranked by descending HRW weight (ties break by
/// index, which cannot recur for distinct addresses in practice).
pub fn rank<S: AsRef<str>>(key: u64, backends: &[S]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..backends.len()).collect();
    idx.sort_by_key(|&i| (std::cmp::Reverse(weight(key, backends[i].as_ref())), i));
    idx
}

/// The top-ranked backend for `key` (`None` for an empty fleet).
pub fn pick<S: AsRef<str>>(key: u64, backends: &[S]) -> Option<usize> {
    rank(key, backends).into_iter().next()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:7733")).collect()
    }

    #[test]
    fn rank_is_a_deterministic_permutation() {
        let backends = fleet(5);
        let r1 = rank(42, &backends);
        let r2 = rank(42, &backends);
        assert_eq!(r1, r2);
        let mut sorted = r1.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
        assert_ne!(rank(42, &backends), rank(43, &backends), "keys spread");
    }

    #[test]
    fn keys_spread_over_the_fleet() {
        let backends = fleet(5);
        let mut hits = vec![0usize; backends.len()];
        for key in 0..2000u64 {
            hits[pick(mix64(key), &backends).unwrap()] += 1;
        }
        for (i, h) in hits.iter().enumerate() {
            // Expected 400 per backend; a 2× band is a loose sanity check
            // that the mix is not degenerate.
            assert!((200..=800).contains(h), "backend {i} got {h} of 2000");
        }
    }

    #[test]
    fn removing_a_backend_moves_only_its_own_keys() {
        let full = fleet(5);
        let removed = 2usize;
        let rest: Vec<String> = full
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != removed)
            .map(|(_, b)| b.clone())
            .collect();
        let mut moved = 0usize;
        let mut owned_by_removed = 0usize;
        for key in 0..2000u64 {
            let key = mix64(key);
            let before = pick(key, &full).unwrap();
            let after = &rest[pick(key, &rest).unwrap()];
            if before == removed {
                owned_by_removed += 1;
            } else if &full[before] != after {
                moved += 1;
            }
        }
        assert_eq!(moved, 0, "keys not owned by the removed backend stay put");
        assert!(owned_by_removed > 0, "the removed backend owned something");
    }

    #[test]
    fn adding_a_backend_moves_about_one_over_n_keys() {
        let old = fleet(5);
        let mut new = old.clone();
        new.push("10.0.0.99:7733".into());
        let n_keys = 3000u64;
        let mut moved = 0usize;
        for key in 0..n_keys {
            let key = mix64(key);
            let before = &old[pick(key, &old).unwrap()];
            let after = &new[pick(key, &new).unwrap()];
            if before != after {
                // HRW guarantee: a key only ever moves TO the new backend.
                assert_eq!(after, "10.0.0.99:7733");
                moved += 1;
            }
        }
        let expect = n_keys as f64 / new.len() as f64;
        let ratio = moved as f64 / expect;
        assert!(
            (0.5..=1.5).contains(&ratio),
            "moved {moved}, expected ≈ {expect:.0}"
        );
    }

    #[test]
    fn empty_fleet_has_no_pick() {
        assert_eq!(pick::<String>(1, &[]), None);
    }
}
