//! TCP front end for the resident [`Service`]: accept loop, bounded
//! connection pool, per-connection reader/writer threads, admission
//! backpressure, graceful drain.
//!
//! Life of a connection: the accept loop (one thread, non-blocking accept
//! so shutdown never hangs on `accept(2)`) exchanges preambles, rejects
//! with a typed `busy` frame when the pool is at `max_conns`, and
//! otherwise spawns a *reader* thread. The reader parses control frames
//! and executes ops against the shared [`Service`]; responses go through
//! an mpsc channel to a *writer* thread that owns the socket's write half,
//! so a slow peer never blocks request parsing. Submissions that hit the
//! `JobQueue` admission limit come back as a typed `busy` frame — the
//! server never queues unboundedly on behalf of a client.
//!
//! Shutdown (`{"op":"shutdown"}` or [`NetServer::shutdown`]) is a drain:
//! admissions close, in-flight jobs finish, the final metrics are the
//! reply, and only then do the threads join.

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::frame::{self, Frame, FrameReader, FrameWriter};
use crate::config::{NetConfig, ServiceConfig};
use crate::metrics::{keys, Metrics};
use crate::service::{JobId, JobSpec, Service};
use crate::telemetry::{self, http::MetricsHttp, TsRing};
use crate::trace::Layer;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Net-layer counters, folded into the service metrics under `"net"`.
#[derive(Default)]
pub struct NetStats {
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    pub conns_accepted: AtomicU64,
    pub conns_active: AtomicUsize,
    pub conns_peak: AtomicU64,
    /// Connections turned away at the pool limit.
    pub rejects_conn: AtomicU64,
    /// Submissions turned away by admission control (typed `busy`).
    pub rejects_busy: AtomicU64,
    /// Stores installed through the chunked-push path.
    pub pushes: AtomicU64,
    /// Raw (decompressed) bytes landed by completed pushes.
    pub push_bytes: AtomicU64,
    /// `push_begin` requests answered by dedup.
    pub push_dedups: AtomicU64,
    /// Pushes aborted mid-transfer (no partial store left behind).
    pub push_aborts: AtomicU64,
}

impl NetStats {
    fn add_io(&self, reader: Option<(u64, u64)>, writer: Option<(u64, u64)>) {
        if let Some((b, f)) = reader {
            self.bytes_in.fetch_add(b, Ordering::Relaxed);
            self.frames_in.fetch_add(f, Ordering::Relaxed);
        }
        if let Some((b, f)) = writer {
            self.bytes_out.fetch_add(b, Ordering::Relaxed);
            self.frames_out.fetch_add(f, Ordering::Relaxed);
        }
    }

    /// Fold the counters into a [`Metrics`] snapshot.
    pub fn account(&self, m: &mut Metrics) {
        m.add(keys::NET_BYTES_IN, self.bytes_in.load(Ordering::Relaxed));
        m.add(keys::NET_BYTES_OUT, self.bytes_out.load(Ordering::Relaxed));
        m.add(keys::NET_FRAMES_IN, self.frames_in.load(Ordering::Relaxed));
        m.add(keys::NET_FRAMES_OUT, self.frames_out.load(Ordering::Relaxed));
        m.add(keys::NET_CONNS, self.conns_accepted.load(Ordering::Relaxed));
        m.set_max(keys::NET_CONN_PEAK, self.conns_peak.load(Ordering::Relaxed));
        m.add(keys::NET_REJECTS_CONN, self.rejects_conn.load(Ordering::Relaxed));
        m.add(keys::NET_REJECTS_BUSY, self.rejects_busy.load(Ordering::Relaxed));
        m.add(keys::NET_PUSHES, self.pushes.load(Ordering::Relaxed));
        m.add(keys::NET_PUSH_BYTES, self.push_bytes.load(Ordering::Relaxed));
        m.add(keys::NET_PUSH_DEDUPS, self.push_dedups.load(Ordering::Relaxed));
        m.add(keys::NET_PUSH_ABORTS, self.push_aborts.load(Ordering::Relaxed));
    }
}

/// What a reader hands its connection's writer thread. `pub(crate)` so
/// the TP session driver (`net::tp::serve_tp`), which owns the reader the
/// way a push does, can enqueue its frames through the same single-writer
/// channel instead of racing the writer thread for the socket.
pub(crate) enum Out {
    Ctrl(Json),
    /// A packed TP data-plane frame (`frame::encode_tp`).
    Tp(Vec<u8>),
    Payload(Vec<u8>),
}

struct Shared {
    svc: Service,
    net: NetConfig,
    stats: NetStats,
    /// Time-series ring the telemetry sampler writes every interval
    /// (`telemetry` op + `fastmps top`).
    ring: TsRing,
    /// Close connections and stop the accept loop.
    stop: AtomicBool,
    /// A client asked for shutdown; `run_until_shutdown` observes this.
    shutdown_requested: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl Shared {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Service metrics with the net counters attached.
    fn metrics_json(&self) -> Json {
        let mut net = Metrics::new();
        self.stats.account(&mut net);
        match self.svc.metrics_json() {
            Json::Obj(mut m) => {
                m.insert("net".into(), net.to_json());
                Json::Obj(m)
            }
            other => other,
        }
    }

    /// One telemetry sample off the live service — a few atomic loads,
    /// two short lock holds, no registry clone, no allocation beyond
    /// the fixed-size histogram copy on the stack.
    fn telemetry_sample(&self) -> telemetry::TsSample {
        let q = self.svc.queue();
        let (submitted, _rejected, completed, failed) = q.job_counters();
        let qw = q.queue_wait_stats();
        let (samples_done, steps) = self
            .svc
            .with_metrics(|m| (m.get(keys::SAMPLES), m.get(keys::STEPS)));
        let hits = self.svc.cache().hits();
        let lookups = hits + self.svc.cache().misses();
        telemetry::TsSample {
            unix_ms: telemetry::now_unix_ms(),
            queue_depth: q.depth() as u64,
            inflight_batches: self.svc.inflight_batches() as u64,
            cache_hit_rate: if lookups > 0 {
                Some(hits as f64 / lookups as f64)
            } else {
                None
            },
            jobs_submitted: submitted,
            jobs_completed: completed,
            jobs_failed: failed,
            samples_done,
            steps,
            net_bytes_in: self.stats.bytes_in.load(Ordering::Relaxed),
            net_bytes_out: self.stats.bytes_out.load(Ordering::Relaxed),
            queue_wait_p50: qw.quantile(0.5),
            queue_wait_p99: qw.quantile(0.99),
            rtt_p50: None,
            rtt_p99: None,
        }
    }

    /// Stop admissions and block until every in-flight job is terminal.
    fn drain(&self, cap: Duration) {
        self.svc.queue().shutdown();
        let deadline = Instant::now() + cap;
        let mut delay = Duration::from_millis(1);
        while !self.svc.idle() && Instant::now() < deadline {
            std::thread::sleep(delay);
            delay = (delay * 2).min(Duration::from_millis(50));
        }
    }
}

/// A running TCP front end. Dropping it stops and joins everything.
pub struct NetServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    sampler: Option<JoinHandle<()>>,
    exporter: Option<MetricsHttp>,
}

impl NetServer {
    /// Start a [`Service`] and listen on `net.addr` (use port 0 for an
    /// ephemeral port; see [`NetServer::local_addr`]).
    pub fn start(cfg: ServiceConfig, net: NetConfig) -> Result<NetServer> {
        net.validate()?;
        let svc = Service::start(cfg)?;
        if let Some(dir) = net.push_dir.as_deref() {
            // Restart recovery: stores installed by a previous process
            // stay resolvable by content key; crashed staging dirs go.
            super::push::register_existing(svc.cache(), dir);
        }
        let listener =
            TcpListener::bind(&net.addr).map_err(|e| Error::io(format!("bind {}", net.addr), e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::io("local_addr", e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::io("set_nonblocking", e))?;
        let shared = Arc::new(Shared {
            svc,
            net,
            stats: NetStats::default(),
            ring: TsRing::new(telemetry::RING_CAPACITY),
            stop: AtomicBool::new(false),
            shutdown_requested: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
        });
        // Start the exporter before any thread spawns: a bind failure
        // on the metrics address aborts startup cleanly (dropping
        // `shared` joins the service).
        let exporter = match shared.net.metrics_listen.clone() {
            Some(listen) => {
                let sh = shared.clone();
                let render: telemetry::http::RenderFn =
                    Arc::new(move || telemetry::prom::render_document(&sh.metrics_json()));
                Some(MetricsHttp::start(&listen, render)?)
            }
            None => None,
        };
        let sampler = {
            let shared = shared.clone();
            std::thread::spawn(move || telemetry_loop(shared))
        };
        let accept = {
            let shared = shared.clone();
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        Ok(NetServer {
            shared,
            addr,
            accept: Some(accept),
            sampler: Some(sampler),
            exporter,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Where the Prometheus `/metrics` endpoint listens (resolves port
    /// 0); `None` unless `metrics_listen` is configured.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.exporter.as_ref().map(|e| e.local_addr())
    }

    /// The service behind the listener (for embedding and tests).
    pub fn service(&self) -> &Service {
        &self.shared.svc
    }

    /// Current metrics (service + net counters).
    pub fn metrics_json(&self) -> Json {
        self.shared.metrics_json()
    }

    /// True once a client's `shutdown` op has drained the service.
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Block until a client requests shutdown or `max_secs` elapses.
    pub fn run_until_shutdown(&self, max_secs: Option<f64>) {
        let t0 = Instant::now();
        while !self.shutdown_requested() && !self.shared.stopping() {
            if let Some(max) = max_secs {
                if t0.elapsed().as_secs_f64() >= max {
                    return;
                }
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    fn stop_and_join(&mut self) {
        // Drain jobs first so in-flight work lands before sockets close.
        self.shared.drain(Duration::from_secs(600));
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sampler.take() {
            let _ = h.join();
        }
        if let Some(mut e) = self.exporter.take() {
            e.shutdown();
        }
        let conns: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
    }

    /// Drain jobs, close the listener and all connections, join every
    /// thread, and return the final metrics.
    pub fn shutdown(mut self) -> Json {
        self.stop_and_join();
        let shared = self.shared.clone();
        drop(self); // Drop sees accept == None and joined conns: no-op work
        match Arc::try_unwrap(shared) {
            Ok(inner) => {
                let mut net = Metrics::new();
                inner.stats.account(&mut net);
                match inner.svc.shutdown() {
                    Json::Obj(mut m) => {
                        m.insert("net".into(), net.to_json());
                        Json::Obj(m)
                    }
                    other => other,
                }
            }
            // A connection thread leaked a reference (should not happen);
            // fall back to the racy-but-close snapshot.
            Err(shared) => shared.metrics_json(),
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Background telemetry sampler: one ring snapshot immediately (so
/// `top` has data right after boot), then one per interval until stop.
/// The sleep is chopped into ≤ 10 ms ticks so shutdown never waits out
/// a full interval.
fn telemetry_loop(shared: Arc<Shared>) {
    loop {
        shared.ring.snapshot(shared.telemetry_sample());
        let deadline =
            Instant::now() + Duration::from_millis(shared.net.telemetry_interval_ms);
        loop {
            if shared.stopping() {
                return;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            std::thread::sleep(left.min(Duration::from_millis(10)));
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    while !shared.stopping() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                handle_accept(stream, &shared);
                reap_conns(&shared.conns);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Join connection threads that already finished so the handle list does
/// not grow for the life of a busy server (shared with the routing tier).
pub(crate) fn reap_conns(conns: &Mutex<Vec<JoinHandle<()>>>) {
    let mut g = conns.lock().unwrap();
    let mut i = 0;
    while i < g.len() {
        if g[i].is_finished() {
            let _ = g.swap_remove(i).join();
        } else {
            i += 1;
        }
    }
}

/// Detached lame-duck rejection (shared with the routing tier): deliver
/// the typed `busy` frame, then hold the socket open (draining, ≤ 5 s)
/// until the peer closes — an immediate close would let a client write
/// mid-request and have the kernel RST the rejection frame out of its
/// buffer.
pub(crate) fn lame_duck_reject(stream: TcpStream, write_timeout_ms: u64) {
    std::thread::spawn(move || {
        let _ = stream.set_write_timeout(Some(Duration::from_millis(write_timeout_ms.max(1))));
        let read_half = stream.try_clone();
        let mut w = FrameWriter::new(BufWriter::new(stream));
        if w.write_preamble().is_err() {
            return;
        }
        let _ = w.write_ctrl(&reply_err("busy", "connection limit reached"));
        if let Ok(mut r) = read_half {
            let _ = r.set_read_timeout(Some(Duration::from_secs(5)));
            let mut buf = [0u8; 256];
            while matches!(std::io::Read::read(&mut r, &mut buf), Ok(n) if n > 0) {}
        }
    });
}

fn handle_accept(stream: TcpStream, shared: &Arc<Shared>) {
    let stats = &shared.stats;
    let prev = stats.conns_active.fetch_add(1, Ordering::SeqCst);
    if prev >= shared.net.max_conns {
        stats.conns_active.fetch_sub(1, Ordering::SeqCst);
        stats.rejects_conn.fetch_add(1, Ordering::Relaxed);
        lame_duck_reject(stream, shared.net.write_timeout_ms);
        return;
    }
    stats.conns_accepted.fetch_add(1, Ordering::Relaxed);
    stats
        .conns_peak
        .fetch_max((prev + 1) as u64, Ordering::Relaxed);
    let shared2 = shared.clone();
    let handle = std::thread::spawn(move || {
        connection(stream, &shared2);
        shared2.stats.conns_active.fetch_sub(1, Ordering::SeqCst);
    });
    shared.conns.lock().unwrap().push(handle);
}

/// Typed error reply (shared with the routing tier, which speaks the
/// same frame vocabulary on its listen side).
pub(crate) fn reply_err(kind: &str, msg: impl std::fmt::Display) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("type", Json::Str(kind.into())),
        ("error", Json::Str(msg.to_string())),
    ])
}

pub(crate) fn reply_ok(kind: &str, mut extra: Vec<(&str, Json)>) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("type", Json::Str(kind.into())),
    ];
    fields.append(&mut extra);
    Json::obj(fields)
}

/// Reader half of one connection (runs on the connection thread).
fn connection(stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(
        shared.net.read_timeout_ms.max(1),
    )));
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = write_half.set_write_timeout(Some(Duration::from_millis(
        shared.net.write_timeout_ms.max(1),
    )));

    let (tx, rx) = std::sync::mpsc::channel::<Out>();
    let writer = {
        let shared = shared.clone();
        std::thread::spawn(move || writer_loop(write_half, rx, shared))
    };

    let mut reader = FrameReader::new(BufReader::new(stream), shared.net.max_frame_bytes);
    let outcome = reader_loop(&mut reader, &tx, shared);
    shared.stats.add_io(Some(reader.drain_counters()), None);
    if let Err(e) = outcome {
        if !frame::is_timeout(&e) {
            // Parse/protocol failure: tell the peer why before closing.
            let _ = tx.send(Out::Ctrl(reply_err("error", &e)));
        }
    }
    drop(tx); // writer drains queued replies, then exits
    let _ = writer.join();
}

fn reader_loop(
    reader: &mut FrameReader<BufReader<TcpStream>>,
    tx: &Sender<Out>,
    shared: &Arc<Shared>,
) -> Result<()> {
    reader.read_preamble()?;
    loop {
        if shared.stopping() {
            return Ok(());
        }
        let msg = match reader.read_frame_idle()? {
            None => continue, // idle tick: re-check the stop flag
            Some(Frame::Payload(_) | Frame::Chunk(_) | Frame::Tp(_)) => {
                return Err(Error::format(
                    "net wire: unexpected binary frame from client",
                ));
            }
            Some(Frame::Ctrl(msg)) => msg,
        };
        shared.stats.add_io(Some(reader.drain_counters()), None);
        if msg.get("op").and_then(|v| v.as_str()) == Some("push_begin") {
            // Push owns the reader until push_end (chunk frames are only
            // meaningful inside a push), so it is driven from here rather
            // than handle_op.
            let mut send = |j: Json| {
                tx.send(Out::Ctrl(j))
                    .map_err(|_| Error::other("net: writer thread gone"))
            };
            let mut observe_chunk =
                |secs: f64| shared.svc.observe(keys::HIST_PUSH_CHUNK, secs);
            super::push::serve_push(
                &msg,
                reader,
                &mut send,
                shared.svc.cache(),
                &shared.net,
                &shared.stats,
                &shared.stop,
                &mut observe_chunk,
            )?;
            shared.stats.add_io(Some(reader.drain_counters()), None);
            continue;
        }
        if msg.get("op").and_then(|v| v.as_str()) == Some("tp_hello") {
            // A TP group leader adopting this backend as a follower. Like
            // a push, the session owns the reader until the group winds
            // down (TP frames are only meaningful inside a session);
            // builds that predate TP never reach here — their handle_op
            // answers `tp_hello` with the typed unknown-op error, which
            // is exactly the version-skew contract of docs/PROTOCOL.md.
            let t_tp = Instant::now();
            super::tp::serve_tp(&msg, reader, tx, &shared.svc, &shared.net, &shared.stop)?;
            shared.svc.recorder().span(
                Layer::Net,
                "op_tp_hello",
                0,
                msg.get("trace")
                    .and_then(|v| v.as_str())
                    .and_then(crate::trace::parse_trace_id)
                    .unwrap_or(0),
                t_tp.elapsed().as_nanos() as u64,
                0,
            );
            shared.stats.add_io(Some(reader.drain_counters()), None);
            continue;
        }
        // One Net-layer span per control op, attributed to the job when
        // the op names one (decode happened in read_frame; this span is
        // the server-side handling time a client's RTT is made of).
        let op = msg.get("op").and_then(|v| v.as_str()).unwrap_or("");
        let op_job = msg
            .get("id")
            .and_then(|v| v.as_f64())
            .filter(|v| *v >= 0.0 && v.fract() == 0.0)
            .map(|v| v as u64)
            .unwrap_or(0);
        let t_op = Instant::now();
        let keep = handle_op(&msg, tx, shared)?;
        let trace = if op_job != 0 {
            shared.svc.queue().trace_of(op_job)
        } else {
            // A submit carries its trace id inside the job spec.
            msg.get("job")
                .and_then(|j| j.get("trace"))
                .and_then(|v| v.as_str())
                .and_then(crate::trace::parse_trace_id)
                .unwrap_or(0)
        };
        shared.svc.recorder().span(
            Layer::Net,
            op_span_name(op),
            op_job,
            trace,
            t_op.elapsed().as_nanos() as u64,
            0,
        );
        if !keep {
            return Ok(());
        }
    }
}

/// Static span name for a control op (ring slots hold `&'static str`).
fn op_span_name(op: &str) -> &'static str {
    match op {
        "ping" => "op_ping",
        "submit" => "op_submit",
        "status" => "op_status",
        "wait" => "op_wait",
        "cancel" => "op_cancel",
        "list" => "op_list",
        "metrics" => "op_metrics",
        "telemetry" => "op_telemetry",
        "trace" => "op_trace",
        "tp_hello" => "op_tp_hello",
        "shutdown" => "op_shutdown",
        _ => "op_other",
    }
}

/// Execute one control op; `Ok(false)` closes the connection.
fn handle_op(msg: &Json, tx: &Sender<Out>, shared: &Arc<Shared>) -> Result<bool> {
    let op = msg.get("op").and_then(|v| v.as_str()).unwrap_or("");
    let send = |j: Json| {
        tx.send(Out::Ctrl(j))
            .map_err(|_| Error::other("net: writer thread gone"))
    };
    match op {
        "ping" => send(reply_ok("pong", vec![]))?,
        "submit" => {
            let spec = JobSpec::from_json(msg.req("job")?)?;
            match shared.svc.submit(spec) {
                Ok(id) => send(reply_ok("submitted", vec![("id", Json::Num(id as f64))]))?,
                Err(Error::Busy(m)) => {
                    shared.stats.rejects_busy.fetch_add(1, Ordering::Relaxed);
                    send(reply_err("busy", m))?;
                }
                Err(e) => send(reply_err("error", e))?,
            }
        }
        "status" => {
            let id = req_id(msg)?;
            match shared.svc.queue().status(id) {
                Some(v) => send(reply_ok("status", vec![("job", v.to_json())]))?,
                None => send(reply_err("error", format!("unknown job {id}")))?,
            }
        }
        "wait" => {
            let id = req_id(msg)?;
            let timeout_ms = msg
                .get("timeout_ms")
                .and_then(|v| v.as_f64())
                .filter(|t| *t >= 0.0)
                .unwrap_or(60_000.0)
                .min(600_000.0);
            match shared.svc.wait(id, Duration::from_millis(timeout_ms as u64)) {
                None => send(reply_err("error", format!("unknown job {id}")))?,
                Some(st) if st.is_terminal() => {
                    let result = shared
                        .svc
                        .queue()
                        .result_json(id)
                        .unwrap_or_else(|| reply_err("error", "result evicted"));
                    let sink = shared.svc.queue().job_sink(id);
                    send(reply_ok(
                        "result",
                        vec![
                            ("result", result),
                            ("payload", Json::Bool(sink.is_some())),
                        ],
                    ))?;
                    if let Some(s) = sink {
                        let t0 = Instant::now();
                        let packed = frame::pack_sink(&s);
                        shared.svc.recorder().span(
                            Layer::Sink,
                            "encode",
                            id,
                            shared.svc.queue().trace_of(id),
                            t0.elapsed().as_nanos() as u64,
                            packed.len() as u64,
                        );
                        tx.send(Out::Payload(packed))
                            .map_err(|_| Error::other("net: writer thread gone"))?;
                    }
                }
                Some(_) => {
                    // Still running at the client's timeout: report status.
                    let v = shared.svc.queue().status(id);
                    match v {
                        Some(v) => send(reply_ok("status", vec![("job", v.to_json())]))?,
                        None => send(reply_err("error", format!("unknown job {id}")))?,
                    }
                }
            }
        }
        "cancel" => {
            let id = req_id(msg)?;
            match shared.svc.queue().status(id) {
                None => send(reply_err("error", format!("unknown job {id}")))?,
                Some(_) => {
                    shared.svc.queue().fail_job(id, "cancelled by client");
                    send(reply_ok("cancelled", vec![("id", Json::Num(id as f64))]))?;
                }
            }
        }
        "list" => {
            let mut views = shared.svc.queue().snapshot();
            crate::service::job::sort_views(&mut views);
            let jobs = Json::Arr(views.iter().map(|v| v.to_json()).collect());
            send(reply_ok("jobs", vec![("jobs", jobs)]))?;
        }
        "metrics" => {
            send(reply_ok("metrics", vec![("metrics", shared.metrics_json())]))?;
        }
        "telemetry" => {
            send(reply_ok(
                "telemetry",
                vec![
                    (
                        "interval_ms",
                        Json::Num(shared.net.telemetry_interval_ms as f64),
                    ),
                    ("samples", shared.ring.to_json()),
                ],
            ))?;
        }
        "trace" => {
            // Either filter may be present: a job id, a 16-hex trace id,
            // or both. The reply carries the flattened `trace_json`
            // fields so `fastmps trace` renders it directly.
            let id = msg
                .get("id")
                .and_then(|v| v.as_f64())
                .filter(|v| *v >= 0.0 && v.fract() == 0.0)
                .map(|v| v as JobId)
                .unwrap_or(0);
            let trace = msg
                .get("trace")
                .and_then(|v| v.as_str())
                .and_then(crate::trace::parse_trace_id)
                .unwrap_or(0);
            match shared.svc.trace_json(id, trace) {
                Json::Obj(fields) => {
                    let extra: Vec<(String, Json)> = fields.into_iter().collect();
                    let mut reply = reply_ok("trace", vec![]);
                    if let Json::Obj(m) = &mut reply {
                        for (k, v) in extra {
                            m.insert(k, v);
                        }
                    }
                    send(reply)?;
                }
                other => send(reply_ok("trace", vec![("events", other)]))?,
            }
        }
        "shutdown" => {
            shared.drain(Duration::from_secs(600));
            // Flag before the reply is enqueued: a client that has seen
            // the reply must never observe shutdown_requested() == false.
            // The reply still flushes — the writer drains its channel
            // before exiting, and joins happen after that.
            shared.shutdown_requested.store(true, Ordering::SeqCst);
            send(reply_ok(
                "shutdown",
                vec![("metrics", shared.metrics_json())],
            ))?;
            return Ok(false);
        }
        other => send(reply_err("error", format!("unknown op '{other}'")))?,
    }
    Ok(true)
}

fn req_id(msg: &Json) -> Result<JobId> {
    msg.req("id")?
        .as_f64()
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .map(|v| v as JobId)
        .ok_or_else(|| Error::format("net: 'id' is not a job id"))
}

fn writer_loop(stream: TcpStream, rx: Receiver<Out>, shared: Arc<Shared>) {
    let mut w = FrameWriter::new(BufWriter::new(stream));
    if w.write_preamble().is_err() {
        return;
    }
    for out in rx {
        let r = match out {
            Out::Ctrl(j) => w.write_ctrl(&j),
            Out::Tp(p) => w.write_tp(&p),
            Out::Payload(p) => {
                // Sample-block flush — the last hop of a job's lifecycle.
                let t0 = Instant::now();
                let r = w.write_payload(&p);
                shared.svc.recorder().span(
                    Layer::Sink,
                    "flush",
                    0,
                    0,
                    t0.elapsed().as_nanos() as u64,
                    p.len() as u64,
                );
                r
            }
        };
        shared.stats.add_io(None, Some(w.drain_counters()));
        if r.is_err() {
            return; // peer went away; reader will notice on its next read
        }
    }
}
