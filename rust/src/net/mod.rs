//! Network transport for the resident sampling service.
//!
//! The file-based job directory (`service::api`) is fine for one machine;
//! this subsystem is the servable front end: the same [`Service`] core
//! behind a TCP listener, speaking a small versioned wire protocol
//! ("FMPN", documented in `docs/PROTOCOL.md`):
//!
//! - [`frame`] — magic/version preamble, varint-length-prefixed frames,
//!   NDJSON control messages, and binary payload frames that carry
//!   LZ-compressed [`SampleSink`] blocks so tensors never transit as
//!   escaped JSON;
//! - [`server`] — accept loop with a bounded connection pool,
//!   per-connection reader/writer threads, admission backpressure (typed
//!   `busy` frames instead of unbounded queueing), graceful drain;
//! - [`client`] — a blocking connect/submit/wait/stream library used by
//!   `fastmps submit --connect` and the integration tests;
//! - [`push`] — chunked, content-addressed store upload (`fastmps push`):
//!   a client streams a `GammaStore` to a server (or through the router
//!   to the affinity backend) in pipelined, independently compressed
//!   chunks, so fleets need no shared data volume;
//! - [`tp`] — the tensor-parallel data plane (`docs/TENSOR_PARALLEL.md`):
//!   a group leader drives column-sharded followers through per-chunk
//!   env broadcasts and partial gathers, bit-identical to a serial walk.
//!
//! Everything is `std::net` + threads — the crate stays dependency-free
//! and offline-buildable.
//!
//! [`Service`]: crate::service::Service
//! [`SampleSink`]: crate::sampler::sink::SampleSink

pub mod client;
pub mod frame;
pub mod push;
pub mod server;
pub(crate) mod tp;

pub use client::{Client, JobResult, PushReport};
pub use server::{NetServer, NetStats};
