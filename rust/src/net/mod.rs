//! Network transport for the resident sampling service.
//!
//! The file-based job directory (`service::api`) is fine for one machine;
//! this subsystem is the servable front end: the same [`Service`] core
//! behind a TCP listener, speaking a small versioned wire protocol
//! ("FMPN", documented in `docs/PROTOCOL.md`):
//!
//! - [`frame`] — magic/version preamble, varint-length-prefixed frames,
//!   NDJSON control messages, and binary payload frames that carry
//!   LZ-compressed [`SampleSink`] blocks so tensors never transit as
//!   escaped JSON;
//! - [`server`] — accept loop with a bounded connection pool,
//!   per-connection reader/writer threads, admission backpressure (typed
//!   `busy` frames instead of unbounded queueing), graceful drain;
//! - [`client`] — a blocking connect/submit/wait/stream library used by
//!   `fastmps submit --connect` and the integration tests.
//!
//! Everything is `std::net` + threads — the crate stays dependency-free
//! and offline-buildable.
//!
//! [`Service`]: crate::service::Service
//! [`SampleSink`]: crate::sampler::sink::SampleSink

pub mod client;
pub mod frame;
pub mod server;

pub use client::{Client, JobResult};
pub use server::{NetServer, NetStats};
