//! Server half of the chunked store push (`push_begin` → CHUNK frames →
//! `push_end`; see `docs/PROTOCOL.md` § Chunked store push).
//!
//! Lifecycle of one push:
//!
//! 1. `push_begin` announces the store's content key (manifest hash), its
//!    exact raw stream size, and the chunk count. The server dedups by
//!    key (the store may already be cached, registered, or installed on
//!    disk), enforces the staging quota, and replies `push_ready`.
//! 2. CHUNK frames arrive pipelined — the client compresses chunk *k+1*
//!    while *k* is on the wire, and the server decompresses and writes
//!    chunk *k* while *k+1* transits: ingest mirrors the paper's
//!    compute/I-O overlap. Each chunk carries its index and the running
//!    FNV-1a of all raw bytes so far, so loss, reorder, or corruption is
//!    caught at the first affected chunk.
//! 3. `push_end` closes the books: chunk count, byte count, checksum,
//!    staged manifest hash, and a full `GammaStore::open` validation all
//!    must agree before the staging directory is atomically renamed into
//!    place and the store is installed in the `StoreCache`.
//!
//! Failure at any point — disconnect, stall, checksum mismatch, hostile
//! stream — removes the staging directory and touches neither the cache
//! nor the install root: a partial store is never visible.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

use super::frame::{self, Frame, FrameReader};
use super::server::{reply_err, reply_ok, NetStats};
use crate::config::NetConfig;
use crate::io::{manifest_hash_at, GammaStore, StoreStreamWriter};
use crate::service::StoreCache;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::Fnv1a;

/// Install directory of a pushed store under the push root.
pub fn store_dir(push_dir: &Path, key: u64) -> PathBuf {
    push_dir.join(format!("store-{key:016x}"))
}

/// Distinguishes concurrent staging dirs for the same key.
static STAGING_NONCE: AtomicU64 = AtomicU64::new(0);

/// Scan `push_dir` for previously installed stores (`store-*`), register
/// each with the cache, and remove leftover staging directories from a
/// crashed push. Returns the number of stores registered.
pub fn register_existing(cache: &StoreCache, push_dir: &Path) -> usize {
    let Ok(entries) = std::fs::read_dir(push_dir) else {
        return 0;
    };
    let mut n = 0;
    for entry in entries.flatten() {
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with(".staging-") {
            let _ = std::fs::remove_dir_all(&path);
            continue;
        }
        if !name.starts_with("store-") {
            continue;
        }
        // Only re-register installs whose blobs still match the manifest;
        // a directory broken out-of-band must not answer dedup.
        let intact = manifest_hash_at(&path).ok().filter(|_| {
            GammaStore::open(&path)
                .and_then(|s| s.verify_blobs())
                .is_ok()
        });
        if let Some(hash) = intact {
            cache.register(hash, path);
            n += 1;
        }
    }
    n
}

/// Shard identity announced in `push_begin` (routing metadata; the staged
/// manifest's own shard section is the authority and must agree).
pub(crate) struct PushShard {
    pub index: usize,
    pub of: usize,
    /// Manifest hash of the full (unsharded) store.
    pub base: u64,
}

impl PushShard {
    /// Parse the optional `"shard"` object of a `push_begin`.
    pub(crate) fn parse(msg: &Json) -> Result<Option<PushShard>> {
        let Some(s) = msg.get("shard").filter(|v| !matches!(**v, Json::Null)) else {
            return Ok(None);
        };
        let of = s
            .get("of")
            .and_then(|v| v.as_usize())
            .filter(|v| *v >= 2)
            .ok_or_else(|| Error::format("push_begin: shard 'of' is not an integer ≥ 2"))?;
        let index = s
            .get("index")
            .and_then(|v| v.as_usize())
            .filter(|v| *v < of)
            .ok_or_else(|| Error::format("push_begin: shard 'index' is not in 0..of"))?;
        let base = s
            .get("base")
            .and_then(|v| v.as_str())
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or_else(|| Error::format("push_begin: shard 'base' is not a hex store key"))?;
        Ok(Some(PushShard { index, of, base }))
    }
}

/// What `push_begin` announced, validated.
struct PushRequest {
    key: u64,
    total_bytes: u64,
    chunks: u64,
    shard: Option<PushShard>,
}

impl PushRequest {
    fn parse(msg: &Json, net: &NetConfig) -> Result<PushRequest> {
        let key = msg
            .get("key")
            .and_then(|v| v.as_str())
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or_else(|| Error::format("push_begin: missing hex 'key'"))?;
        let total_bytes = msg
            .get("total_bytes")
            .and_then(|v| v.as_f64())
            .filter(|v| *v >= 1.0 && v.fract() == 0.0)
            .ok_or_else(|| Error::format("push_begin: bad 'total_bytes'"))?
            as u64;
        let chunks = msg
            .get("chunks")
            .and_then(|v| v.as_f64())
            .filter(|v| *v >= 1.0 && v.fract() == 0.0)
            .ok_or_else(|| Error::format("push_begin: bad 'chunks'"))?
            as u64;
        if chunks > total_bytes {
            return Err(Error::format("push_begin: more chunks than bytes"));
        }
        if total_bytes > net.push_staging_bytes {
            return Err(Error::format(format!(
                "push of {total_bytes} bytes exceeds the {} byte staging quota",
                net.push_staging_bytes
            )));
        }
        Ok(PushRequest {
            key,
            total_bytes,
            chunks,
            shard: PushShard::parse(msg)?,
        })
    }
}

/// Removes the staging directory unless the push completed.
struct StagingGuard {
    dir: PathBuf,
    armed: bool,
}

impl Drop for StagingGuard {
    fn drop(&mut self) {
        if self.armed {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

/// Handle one `push_begin` on a server connection. Reads CHUNK frames
/// from `reader` until `push_end`; replies through `reply` (the caller's
/// writer channel). Returns `Err` only when the connection must close
/// (the framing is out of sync); well-formed rejections reply inline and
/// return `Ok`.
pub(crate) fn serve_push<R: std::io::Read>(
    msg: &Json,
    reader: &mut FrameReader<R>,
    reply: &mut impl FnMut(Json) -> Result<()>,
    cache: &StoreCache,
    net: &NetConfig,
    stats: &NetStats,
    stop: &AtomicBool,
    observe_chunk: &mut dyn FnMut(f64),
) -> Result<()> {
    let Some(push_dir) = net.push_dir.as_deref() else {
        reply(reply_err(
            "error",
            "store push is disabled on this server (no push dir configured)",
        ))?;
        return Ok(());
    };
    let req = match PushRequest::parse(msg, net) {
        Ok(r) => r,
        Err(e) => {
            // Nothing streamed yet — the client waits for push_ready
            // before sending chunks, so an inline rejection stays in sync.
            reply(reply_err("error", e))?;
            return Ok(());
        }
    };
    let key_hex = format!("{:016x}", req.key);
    let final_dir = store_dir(push_dir, req.key);

    // Dedup by content key: cached, registered, or already on disk from a
    // previous run all count — the client skips the upload entirely.
    if cache.knows(req.key) || installed_at(&final_dir, req.key, cache) {
        stats.push_dedups.fetch_add(1, Ordering::Relaxed);
        reply(reply_ok(
            "push_ready",
            vec![
                ("dedup", Json::Bool(true)),
                ("key", Json::Str(key_hex)),
            ],
        ))?;
        return Ok(());
    }

    std::fs::create_dir_all(push_dir).map_err(|e| Error::io(push_dir.display(), e))?;
    let staging = push_dir.join(format!(
        ".staging-{key_hex}-{}",
        STAGING_NONCE.fetch_add(1, Ordering::Relaxed)
    ));
    let mut guard = StagingGuard {
        dir: staging.clone(),
        armed: true,
    };
    let mut writer = StoreStreamWriter::new(&staging)?;
    reply(reply_ok(
        "push_ready",
        vec![("dedup", Json::Bool(false)), ("key", Json::Str(key_hex.clone()))],
    ))?;

    match receive_chunks(reader, &mut writer, &req, net, stop, observe_chunk) {
        Ok(()) => {}
        Err(e) => {
            stats.push_aborts.fetch_add(1, Ordering::Relaxed);
            return Err(e); // guard removes the staging dir
        }
    }

    // Everything the wire promised checked out; now verify the *content*:
    // the staged manifest must hash to the announced key (it is the
    // routing identity), and the store must open as a valid FMPS1 tree.
    let finalize = (|| -> Result<Json> {
        let staged_hash = manifest_hash_at(&staging)?;
        if staged_hash != req.key {
            return Err(Error::format(format!(
                "pushed manifest hashes to {staged_hash:016x}, announced {key_hex}"
            )));
        }
        let staged = GammaStore::open(&staging)?;
        staged.verify_blobs()?;
        // An announced shard identity must match the manifest's own shard
        // section — a mismatch means the router would record a shard map
        // entry the data on disk does not satisfy.
        if let Some(announced) = &req.shard {
            let matches = staged.shard.as_ref().is_some_and(|s| {
                (s.index, s.of, s.base) == (announced.index, announced.of, announced.base)
            });
            if !matches {
                return Err(Error::format(format!(
                    "push_begin announced shard {}/{} of {:016x}, manifest says {}",
                    announced.index,
                    announced.of,
                    announced.base,
                    staged
                        .shard
                        .as_ref()
                        .map(|s| format!("{}/{} of {:016x}", s.index, s.of, s.base))
                        .unwrap_or_else(|| "no shard".into()),
                )));
            }
        }
        drop(staged);
        match std::fs::rename(&staging, &final_dir) {
            Ok(()) => {}
            Err(_) if final_dir.exists() => {
                // A concurrent push of the same store won the rename —
                // that's a dedup, not a failure.
                let _ = std::fs::remove_dir_all(&staging);
            }
            Err(e) => return Err(Error::io(final_dir.display(), e)),
        }
        let store = std::sync::Arc::new(GammaStore::open(&final_dir)?);
        cache.install(req.key, store);
        stats.pushes.fetch_add(1, Ordering::Relaxed);
        stats
            .push_bytes
            .fetch_add(req.total_bytes, Ordering::Relaxed);
        Ok(reply_ok(
            "pushed",
            vec![
                ("key", Json::Str(key_hex.clone())),
                ("chunks", Json::Num(req.chunks as f64)),
                ("bytes", Json::Num(req.total_bytes as f64)),
                ("dedup", Json::Bool(false)),
            ],
        ))
    })();
    match finalize {
        Ok(ok_reply) => {
            guard.armed = false; // installed (or lost a benign rename race)
            reply(ok_reply)
        }
        Err(e) => {
            stats.push_aborts.fetch_add(1, Ordering::Relaxed);
            // The stream is fully consumed (push_end arrived), so the
            // connection is still in sync — reject inline and keep it.
            reply(reply_err("error", format!("push rejected: {e}")))
        }
    }
}

/// True when a store with `key` is already installed *intact* at `dir`
/// (e.g. from a previous process) — registers it with the cache as a
/// side effect. Blob integrity is part of the check: answering dedup for
/// a directory with a valid manifest but broken blobs would poison the
/// key exactly the way `verify_blobs` at install time exists to prevent.
fn installed_at(dir: &Path, key: u64, cache: &StoreCache) -> bool {
    let intact = manifest_hash_at(dir).map(|h| h == key).unwrap_or(false)
        && GammaStore::open(dir)
            .and_then(|s| s.verify_blobs())
            .is_ok();
    if intact {
        cache.register(key, dir.to_path_buf());
    }
    intact
}

/// Drive the chunk sub-protocol to `push_end`, feeding the staged writer.
/// `observe_chunk` sees the server-side processing time of each chunk
/// (decode + verify + staged write, not the wait on the wire).
fn receive_chunks<R: std::io::Read>(
    reader: &mut FrameReader<R>,
    writer: &mut StoreStreamWriter,
    req: &PushRequest,
    net: &NetConfig,
    stop: &AtomicBool,
    observe_chunk: &mut dyn FnMut(f64),
) -> Result<()> {
    let mut fnv = Fnv1a::new();
    let mut next_index = 0u64;
    let mut raw_total = 0u64;
    let stall_cap = net.push_stall_cap();
    let mut last_frame = Instant::now();
    loop {
        if stop.load(Ordering::SeqCst) {
            return Err(Error::other("server stopping; push aborted"));
        }
        let frame = match reader.read_frame_idle()? {
            Some(f) => f,
            None => {
                if last_frame.elapsed() > stall_cap {
                    return Err(Error::other(format!(
                        "push stalled: no frame for {} ms",
                        stall_cap.as_millis()
                    )));
                }
                continue;
            }
        };
        last_frame = Instant::now();
        match frame {
            Frame::Chunk(packed) => {
                let t_chunk = Instant::now();
                let (index, declared_fnv, raw) = frame::decode_chunk(&packed)?;
                if index != next_index {
                    return Err(Error::format(format!(
                        "push chunk {index} out of order (expected {next_index})"
                    )));
                }
                if next_index >= req.chunks {
                    return Err(Error::format("more push chunks than announced"));
                }
                next_index += 1;
                raw_total += raw.len() as u64;
                if raw_total > req.total_bytes {
                    return Err(Error::format(format!(
                        "push exceeds its announced {} bytes",
                        req.total_bytes
                    )));
                }
                fnv.update(&raw);
                if fnv.digest() != declared_fnv {
                    return Err(Error::format(format!(
                        "running checksum mismatch at chunk {index}"
                    )));
                }
                writer.feed(&raw)?;
                observe_chunk(t_chunk.elapsed().as_secs_f64());
            }
            Frame::Ctrl(m) if m.get("op").and_then(|v| v.as_str()) == Some("push_end") => {
                if next_index != req.chunks {
                    return Err(Error::format(format!(
                        "push_end after {next_index} of {} chunks",
                        req.chunks
                    )));
                }
                if raw_total != req.total_bytes {
                    return Err(Error::format(format!(
                        "push_end at {raw_total} of {} bytes",
                        req.total_bytes
                    )));
                }
                let declared = m
                    .get("checksum")
                    .and_then(|v| v.as_str())
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
                    .ok_or_else(|| Error::format("push_end: missing hex 'checksum'"))?;
                if declared != fnv.digest() {
                    return Err(Error::format("push_end checksum mismatch"));
                }
                if !writer.finished() {
                    return Err(Error::format("push stream ended mid-file"));
                }
                return Ok(());
            }
            Frame::Ctrl(_) => {
                return Err(Error::format(
                    "net wire: unexpected control frame during push",
                ));
            }
            Frame::Payload(_) | Frame::Tp(_) => {
                return Err(Error::format(
                    "net wire: unexpected payload/TP frame during push",
                ));
            }
        }
    }
}
