//! Tensor-parallel data plane over FMPN (`docs/TENSOR_PARALLEL.md`).
//!
//! A TP group runs ONE job across `of` backends, each holding one
//! column shard of every site's Γ (see `GammaStore::write_shard`). The
//! leader (rank 0, the backend the router submitted to) owns the
//! environment, the thresholds, and the measurement; followers own
//! nothing but their Γ columns. Per micro chunk of every site:
//!
//! 1. leader broadcasts the lifted f32 environment ([`TP_ENV`]);
//! 2. every rank contracts it against its own shard — disjoint output
//!    columns, no summation anywhere;
//! 3. the leader gathers the partial `temp` blocks in ascending rank
//!    order ([`TP_PART`]) and assembles the full-width tensor by
//!    placing each block at its shard's column offset;
//! 4. the leader measures (collapse + next environment) exactly like
//!    the serial engine and broadcasts the outcomes ([`TP_OUTCOME`]).
//!
//! Because each output element is produced by exactly one rank with the
//! same k-order GEMM as the serial kernel (`linalg::gemm`), and the
//! "reduce" is a concatenation rather than a floating-point sum, the
//! sharded walk is **bit-identical** to a single backend holding the
//! full store. That is the contract `tests/tp.rs` locks in.
//!
//! The follower side rides an ordinary FMPN connection: a `tp_hello`
//! control op hands the reader to [`serve_tp`] for the life of the
//! group, like a push session. Old builds answer `tp_hello` with the
//! typed unknown-op error and never see a TP frame — the version-skew
//! rule of `docs/PROTOCOL.md`.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::frame::{self, Frame, FrameReader, FrameWriter};
use super::server::{reply_err, reply_ok, Out};
use crate::comm::{tp_op_name, SocketComm, TpLink, TpTransport, TP_DONE, TP_ENV, TP_OUTCOME, TP_PART};
use crate::config::{ComputePrecision, NetConfig, ServiceConfig};
use crate::coordinator::{env_rows, env_store_rows};
use crate::io::{shard_range, DiskModel, GammaStore, Prefetcher};
use crate::linalg::{contract_env_into, contract_env_into_on, matmul_flops, Exec, WorkerPool};
use crate::metrics::{keys, Metrics};
use crate::mps::Site;
use crate::sampler::env::{from_f32_into, to_f32_into};
use crate::sampler::measurement::measure_into_on;
use crate::sampler::sink::SampleSink;
use crate::sampler::{boundary_env, PrepKey, PreparedGamma, PreparedSite, PreparedStore};
use crate::service::{Batch, Service, StoreCache};
use crate::tensor::{Complex, Mat, Tensor3};
use crate::trace::{Layer, Recorder};
use crate::util::error::{Error, Result};
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// f32 wire form of the complex buffers (interleaved re, im — see
// docs/PROTOCOL.md § TP frame grammar)

fn complexes_to_wire(data: &[Complex<f32>], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(data.len() * 2);
    for z in data {
        out.push(z.re);
        out.push(z.im);
    }
}

fn wire_to_mat(w: &[f32], rows: usize, cols: usize, m: &mut Mat<f32>) -> Result<()> {
    if w.len() != rows * cols * 2 {
        return Err(Error::Fabric(format!(
            "TP env payload holds {} floats for a {rows}×{cols} environment",
            w.len()
        )));
    }
    m.rows = rows;
    m.cols = cols;
    m.data.clear();
    m.data
        .extend(w.chunks_exact(2).map(|p| Complex::new(p[0], p[1])));
    Ok(())
}

/// Place the rank-ordered concatenation of shard partials into the
/// full-width `temp` tensor. Block `k` covers columns
/// `shard_range(chi_r_full, k, of)` of every row — disjoint ranges, so
/// assembly is pure placement and cannot move a single bit.
fn assemble_temp(
    gathered: &[f32],
    take: usize,
    d: usize,
    chi_r_full: usize,
    of: usize,
    temp: &mut Tensor3<f32>,
) -> Result<()> {
    temp.reset(take, chi_r_full, d);
    let mut base = 0usize;
    for k in 0..of {
        let (lo, hi) = shard_range(chi_r_full, k, of);
        let w = hi - lo;
        let need = take * w * d * 2;
        let block = gathered.get(base..base + need).ok_or_else(|| {
            Error::Fabric(format!(
                "TP gather came up short: rank {k} block needs {need} floats, {} left",
                gathered.len() - base
            ))
        })?;
        for s in 0..take {
            for y in 0..w {
                for p in 0..d {
                    let src = ((s * w + y) * d + p) * 2;
                    temp.data[(s * chi_r_full + lo + y) * d + p] =
                        Complex::new(block[src], block[src + 1]);
                }
            }
        }
        base += need;
    }
    if base != gathered.len() {
        return Err(Error::Fabric(format!(
            "TP gather carried {} trailing floats past the {of} shard blocks",
            gathered.len() - base
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// The FMPN TpLink: leader's view of one follower

fn wire_fail(peer: &str, what: &str, e: Error) -> Error {
    if frame::is_timeout(&e) {
        Error::Fabric(format!("TP peer {peer} timed out during {what}"))
    } else {
        Error::Fabric(format!("TP peer {peer} hung up during {what}: {e}"))
    }
}

/// One leader→follower link: a dedicated FMPN connection whose reader
/// half the follower parks inside [`serve_tp`] for the group's life.
pub(crate) struct FmpnLink {
    peer: String,
    w: FrameWriter<BufWriter<TcpStream>>,
    r: FrameReader<BufReader<TcpStream>>,
}

impl FmpnLink {
    /// Connect, exchange preambles, send the group hello, await the
    /// typed welcome. A refusal (unknown key, version skew, shard
    /// mismatch) comes back as the follower's own error text.
    pub(crate) fn dial(
        addr: &str,
        hello: &Json,
        timeout_ms: u64,
        max_frame: usize,
    ) -> Result<FmpnLink> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Fabric(format!("TP dial {addr}: {e}")))?;
        let _ = stream.set_nodelay(true);
        let to = Some(Duration::from_millis(timeout_ms.max(1)));
        stream
            .set_read_timeout(to)
            .map_err(|e| Error::io("set_read_timeout", e))?;
        stream
            .set_write_timeout(to)
            .map_err(|e| Error::io("set_write_timeout", e))?;
        let read_half = stream
            .try_clone()
            .map_err(|e| Error::io("clone stream", e))?;
        let mut link = FmpnLink {
            peer: addr.to_string(),
            w: FrameWriter::new(BufWriter::new(stream)),
            r: FrameReader::new(BufReader::new(read_half), max_frame),
        };
        link.w.write_preamble()?;
        link.r
            .read_preamble()
            .map_err(|e| wire_fail(addr, "preamble", e))?;
        link.w.write_ctrl(hello)?;
        let reply = match link.r.read_frame() {
            Ok(Frame::Ctrl(j)) => j,
            Ok(_) => {
                return Err(Error::Fabric(format!(
                    "TP follower {addr} answered the hello with a non-control frame"
                )))
            }
            Err(e) => return Err(wire_fail(addr, "tp_hello", e)),
        };
        if reply.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            let msg = reply
                .get("error")
                .and_then(|v| v.as_str())
                .unwrap_or("refused the group hello");
            return Err(Error::Fabric(format!("TP follower {addr} refused: {msg}")));
        }
        if reply.get("type").and_then(|v| v.as_str()) != Some("tp_welcome") {
            return Err(Error::Fabric(format!(
                "TP follower {addr} sent an unexpected reply to the group hello"
            )));
        }
        Ok(link)
    }
}

impl TpLink for FmpnLink {
    fn send(&mut self, op: u8, seq: u64, data: &[f32]) -> Result<u64> {
        self.w
            .write_tp(&frame::encode_tp(op, seq, data))
            .map_err(|e| wire_fail(&self.peer, tp_op_name(op), e))?;
        Ok((data.len() * 4) as u64)
    }

    fn recv_into(&mut self, op: u8, seq: u64, out: &mut Vec<f32>) -> Result<u64> {
        let f = self
            .r
            .read_frame()
            .map_err(|e| wire_fail(&self.peer, tp_op_name(op), e))?;
        match f {
            Frame::Tp(p) => {
                let before = out.len();
                let (got_op, got_seq) = frame::decode_tp_into(&p, out)?;
                if (got_op, got_seq) != (op, seq) {
                    return Err(Error::Fabric(format!(
                        "TP desync with {}: got ({}, seq {got_seq}), want ({}, seq {seq})",
                        self.peer,
                        tp_op_name(got_op),
                        tp_op_name(op)
                    )));
                }
                Ok(((out.len() - before) * 4) as u64)
            }
            Frame::Ctrl(j) => {
                let msg = j
                    .get("error")
                    .and_then(|v| v.as_str())
                    .unwrap_or("unexpected control frame mid-collective");
                Err(Error::Fabric(format!("TP follower {}: {msg}", self.peer)))
            }
            _ => Err(Error::Fabric(format!(
                "TP follower {} sent a non-TP frame mid-collective",
                self.peer
            ))),
        }
    }

    fn finish(&mut self) -> Result<()> {
        match self.r.read_frame() {
            Ok(Frame::Ctrl(j))
                if j.get("ok").and_then(|v| v.as_bool()) == Some(true)
                    && j.get("type").and_then(|v| v.as_str()) == Some("tp_done") =>
            {
                Ok(())
            }
            Ok(_) => Err(Error::Fabric(format!(
                "TP follower {} did not acknowledge the group teardown",
                self.peer
            ))),
            Err(e) => Err(wire_fail(&self.peer, "tp_done", e)),
        }
    }
}

// ---------------------------------------------------------------------------
// Shared site walk (streaming + residency, the run_batch pattern)

/// Walks a shard store's sites through the prepared-residency chain,
/// streaming only non-resident sites — the same plan `run_batch` uses,
/// so a TP walk inherits the residency economics of a plain one.
struct SiteWalk {
    store: Arc<GammaStore>,
    prep: Arc<PreparedStore>,
    stream_order: Vec<usize>,
    pf: Option<Prefetcher>,
    next_streamed: usize,
    prep_hits: u64,
    prep_convs: u64,
}

impl SiteWalk {
    fn new(store: Arc<GammaStore>, disk: Arc<DiskModel>, prep: Arc<PreparedStore>) -> SiteWalk {
        let m = store.num_sites();
        let stream_order: Vec<usize> = (0..m).filter(|&i| !prep.is_resident(i)).collect();
        let pf = (!stream_order.is_empty())
            .then(|| Prefetcher::new(store.clone(), disk, stream_order.clone(), 2));
        SiteWalk {
            store,
            prep,
            stream_order,
            pf,
            next_streamed: 0,
            prep_hits: 0,
            prep_convs: 0,
        }
    }

    fn site(&mut self, site_idx: usize, metrics: &mut Metrics) -> Result<Arc<PreparedSite>> {
        let from_disk = self.next_streamed < self.stream_order.len()
            && self.stream_order[self.next_streamed] == site_idx;
        if from_disk {
            self.next_streamed += 1;
            let pf = self.pf.as_mut().expect("stream order non-empty");
            let (i, site): (usize, Site) = pf
                .next_site()
                .ok_or_else(|| Error::other("prefetch ended early"))??;
            debug_assert_eq!(i, site_idx);
            metrics.add(keys::IO_OPS, 1);
            metrics.add(keys::IO_BYTES, self.store.site_bytes(site_idx));
            let (ps, converted) = self.prep.site(site_idx, &site);
            if converted {
                self.prep_convs += 1;
            } else {
                self.prep_hits += 1;
            }
            Ok(ps)
        } else {
            let ps = self
                .prep
                .resident(site_idx)
                .ok_or_else(|| Error::other(format!("prepared site {site_idx} vanished mid-walk")))?;
            self.prep_hits += 1;
            Ok(ps)
        }
    }

    fn finish(self, metrics: &mut Metrics) -> Result<()> {
        if let Some(pf) = self.pf {
            metrics.add_phase("io_virtual", pf.io_secs);
            metrics.add_phase("io_stall", pf.stall_secs);
            pf.finish()?;
        }
        metrics.add(keys::STEP_PREP_HITS, self.prep_hits);
        metrics.add(keys::STEP_PREP_CONVERSIONS, self.prep_convs);
        Ok(())
    }
}

fn f32_gamma(p: &PreparedSite) -> Result<&Tensor3<f32>> {
    match &p.gamma {
        PreparedGamma::F32(g) => Ok(g),
        // TP shards cross the wire interleaved and the assemble/gather
        // staging walks interleaved temp buffers, so the TP walk pins
        // `planar: false` in its PrepKey; anything else is a bug.
        _ => Err(Error::other(
            "TP walk found a non-interleaved-f32 prepared site (TP prepares interleaved f32 only)",
        )),
    }
}

// ---------------------------------------------------------------------------
// Leader: the sharded batch walk

/// Run a TP batch as group leader (rank 0). Dials every follower,
/// drives the per-chunk broadcast/contract/gather/measure pipeline, and
/// returns exactly what `run_batch` returns for the worker to complete
/// the job with. Any member loss or desync surfaces as `Error::Fabric`
/// and fails the whole job — TP groups have no partial success.
pub(crate) fn run_batch_tp(
    batch: &Batch,
    cfg: &ServiceConfig,
    cache: &Arc<StoreCache>,
    disk: &Arc<DiskModel>,
    rec: &Arc<Recorder>,
    jobs: &[(u64, u64)],
) -> Result<(Metrics, Vec<SampleSink>)> {
    let tp = batch
        .tp
        .as_ref()
        .ok_or_else(|| Error::other("run_batch_tp dispatched a non-TP batch"))?;
    let store = &batch.store;
    let spec = &store.spec;
    let m = spec.m();
    let d = spec.d();
    if batch.assignments.len() != 1 {
        return Err(Error::other(
            "TP batches carry exactly one job (the dispatcher must not coalesce them)",
        ));
    }
    let a = &batch.assignments[0];
    let rows = a.len;
    if rows == 0 {
        return Err(Error::other("empty TP batch dispatched"));
    }
    if batch.key.compute != ComputePrecision::F32 {
        return Err(Error::config(format!(
            "tensor-parallel jobs run f32 compute only (requested {})",
            batch.key.compute.as_str()
        )));
    }
    if spec.has_displacement() {
        return Err(Error::config(
            "tensor-parallel jobs do not support displaced sampling",
        ));
    }
    let shard = store.shard.as_ref().ok_or_else(|| {
        Error::config("TP job resolved a non-shard store (push the sharded store first)")
    })?;
    if shard.index != 0 {
        return Err(Error::config(format!(
            "TP leader must hold shard 0 of the group, found shard {}",
            shard.index
        )));
    }
    if shard.of != tp.of || shard.base != tp.base {
        return Err(Error::config(format!(
            "TP placement names a {}-way group of base {:016x} but the local shard is {} of {} (base {:016x})",
            tp.of, tp.base, shard.index, shard.of, shard.base
        )));
    }
    if tp.peers.len() + 1 != tp.of {
        return Err(Error::config(format!(
            "TP group of {} needs {} followers, placement carries {}",
            tp.of,
            tp.of - 1,
            tp.peers.len()
        )));
    }
    if shard.full_bonds.len() != m {
        return Err(Error::format(format!(
            "shard manifest lists {} full bonds for {m} sites",
            shard.full_bonds.len()
        )));
    }

    let (job, trace) = jobs.first().copied().unwrap_or((a.job, 0));
    let chunk_max = rows.min(cfg.n2_micro.max(1));
    // Size follower links' frame cap to the largest partial any peer can
    // send back (+ slack for the tiny control acknowledgement).
    let w_max = (0..m)
        .flat_map(|s| (1..tp.of).map(move |k| shard_range(shard.full_bonds[s].1, k, tp.of)))
        .map(|(lo, hi)| hi - lo)
        .max()
        .unwrap_or(0);
    let link_cap = 4096 + chunk_max * w_max.max(1) * d * 8;

    let mut links: Vec<Option<Box<dyn TpLink>>> = vec![None];
    for (i, peer) in tp.peers.iter().enumerate() {
        let hello = Json::obj(vec![
            ("op", Json::Str("tp_hello".into())),
            ("key", Json::Str(format!("{:016x}", peer.key))),
            ("base", Json::Str(format!("{:016x}", tp.base))),
            ("of", Json::Num(tp.of as f64)),
            ("rank", Json::Num((i + 1) as f64)),
            ("rows", Json::Num(rows as f64)),
            ("n2", Json::Num(cfg.n2_micro as f64)),
            ("sites", Json::Num(m as f64)),
            ("compute", Json::Str("f32".into())),
            ("workload", Json::Str(spec.tag().into())),
            ("job", Json::Num(job as f64)),
            ("trace", Json::Str(format!("{trace:016x}"))),
        ]);
        links.push(Some(Box::new(FmpnLink::dial(
            &peer.addr,
            &hello,
            cfg.tp_step_timeout_ms,
            link_cap,
        )?)));
    }
    let mut comm = SocketComm::new(0, links)?;

    let mut metrics = Metrics::new();
    let mut sinks = vec![SampleSink::new(m, d, spec.sink_max_gap())];
    let prep = cache.prepared(
        batch.key.store_hash,
        m,
        PrepKey {
            compute: ComputePrecision::F32,
            gamma_f16: false,
            // Interleaved on purpose: TP tensors go over the wire.
            planar: false,
        },
        cfg.prep_cache_bytes,
    );
    let mut walk = SiteWalk::new(store.clone(), disk.clone(), prep);

    // Session-resident pool: one set of parked workers serves every
    // chunk's contract/measure across the whole walk — no per-step
    // thread spawns (width 1 executes inline).
    let pool = WorkerPool::new(cfg.gemm_threads);
    let exec = Exec::Pooled(&pool);

    let t_group = Instant::now();
    let mut env = boundary_env(rows);
    let mut env_in: Mat<f32> = Mat::zeros(0, 0);
    let mut env_out: Mat<f32> = Mat::zeros(0, 0);
    let mut temp_mine: Tensor3<f32> = Tensor3::zeros(0, 0, 0);
    let mut temp_full: Tensor3<f32> = Tensor3::zeros(0, 0, 0);
    let mut wire: Vec<f32> = Vec::new();
    let mut part: Vec<f32> = Vec::new();
    let mut gathered: Vec<f32> = Vec::new();
    let mut out_wire: Vec<f32> = Vec::new();
    let mut samples_buf: Vec<i32> = Vec::new();
    let mut probs: Vec<f32> = Vec::new();
    let mut ones: Vec<f32> = Vec::new();
    let mut dead_total = 0u64;

    for site_idx in 0..m {
        let psite = walk.site(site_idx, &mut metrics)?;
        let gamma = f32_gamma(&psite)?;
        let (chi_l_full, chi_r_full) = shard.full_bonds[site_idx];
        if gamma.d0 != chi_l_full || gamma.d2 != d {
            return Err(Error::format(format!(
                "shard site {site_idx} is ({},{},{}), manifest promises χ_l {chi_l_full}, d {d}",
                gamma.d0, gamma.d1, gamma.d2
            )));
        }
        // Λ for the full-width measure. Stores in this pipeline fold Λ
        // into Γ and carry the identity (`io::store` and the GBS
        // generator both pin `lambda = 1.0`), so the full-width vector
        // is all ones — bitwise what the serial engine reads from its
        // prepared site. A shard's own lambda is shard-width and unusable
        // here.
        ones.clear();
        ones.resize(chi_r_full, 1.0f32);
        let mut next = crate::tensor::SplitBuf::zeros(&[rows, chi_r_full]);
        let mut site_samples: Vec<i32> = Vec::with_capacity(rows);
        let mut off = 0usize;
        while off < rows {
            let take = (rows - off).min(cfg.n2_micro);
            let mut chunk = env_rows(&env, off, off + take);
            to_f32_into(&chunk, ComputePrecision::F32, &mut env_in)?;

            complexes_to_wire(&env_in.data, &mut wire);
            let t0 = Instant::now();
            let sent = comm.bcast(TP_ENV, &mut wire, 0)?;
            metrics.add_phase("bcast", t0.elapsed().as_secs_f64());
            metrics.add(keys::TP_BCAST_BYTES, sent);

            let t0 = Instant::now();
            contract_env_into_on(&env_in, gamma, &mut temp_mine, exec, cfg.gemm_split)?;
            metrics.add(
                keys::FLOPS,
                matmul_flops(take, gamma.d0, gamma.d1 * gamma.d2),
            );
            complexes_to_wire(&temp_mine.data, &mut part);
            metrics.add_phase("compute", t0.elapsed().as_secs_f64());

            let t0 = Instant::now();
            let got = comm.gather(TP_PART, &part, &mut gathered, 0)?;
            let reduce_secs = t0.elapsed().as_secs_f64();
            metrics.add_phase("comm", reduce_secs);
            metrics.observe(keys::HIST_TP_REDUCE, reduce_secs);
            metrics.add(keys::TP_REDUCE_BYTES, got);
            assemble_temp(&gathered, take, d, chi_r_full, tp.of, &mut temp_full)?;

            let t0 = Instant::now();
            let th = spec.thresholds(site_idx, a.sample0 + off as u64, take);
            let dead = measure_into_on(
                &temp_full,
                &ones,
                &th,
                cfg.scaling,
                exec,
                &mut env_out,
                &mut samples_buf,
                &mut probs,
            )?;
            dead_total += dead as u64;
            metrics.add_phase("measure", t0.elapsed().as_secs_f64());

            out_wire.clear();
            out_wire.extend(samples_buf.iter().map(|&s| s as f32));
            let t0 = Instant::now();
            let sent = comm.bcast(TP_OUTCOME, &mut out_wire, 0)?;
            metrics.add_phase("bcast", t0.elapsed().as_secs_f64());
            metrics.add(keys::TP_BCAST_BYTES, sent);

            from_f32_into(&env_out, &mut chunk);
            env_store_rows(&mut next, off, &chunk);
            site_samples.extend_from_slice(&samples_buf);
            metrics.add(keys::MICRO_BATCHES, 1);
            off += take;
        }
        sinks[0].record(site_idx, &site_samples);
        env = next;
    }

    let mut done: Vec<f32> = Vec::new();
    comm.bcast(TP_DONE, &mut done, 0)?;
    comm.finish()?;
    walk.finish(&mut metrics)?;
    let (wakeups, park_ns) = pool.take_counters();
    metrics.add(keys::POOL_WAKEUPS, wakeups);
    metrics.add(keys::POOL_PARK_NS, park_ns);
    metrics.add("dead_rows", dead_total);
    metrics.add(keys::TP_JOBS, 1);
    metrics.add(keys::SITES, m as u64);
    metrics.add(keys::SAMPLES, rows as u64);
    metrics.add(keys::MACRO_BATCHES, 1);
    rec.span(
        Layer::Tp,
        "tp_group",
        job,
        trace,
        t_group.elapsed().as_nanos() as u64,
        tp.of as u64,
    );
    Ok((metrics, sinks))
}

// ---------------------------------------------------------------------------
// Follower: the shard-serving session

/// Run the follower side of a TP group on an accepted connection. The
/// reader is parked here until the leader tears the group down; TP
/// frames out go through the connection's single writer thread (`tx`).
///
/// Refusals (unknown key, wrong shard, non-f32 compute, malformed
/// hello) answer with a typed error and return `Ok` — the connection
/// stays usable. `Err` is reserved for wire-level failures mid-group,
/// which close the connection so the leader fails the job.
pub(crate) fn serve_tp(
    msg: &Json,
    reader: &mut FrameReader<BufReader<TcpStream>>,
    tx: &Sender<Out>,
    svc: &Service,
    net: &NetConfig,
    stop: &AtomicBool,
) -> Result<()> {
    let refuse = |text: String| -> Result<()> {
        let _ = tx.send(Out::Ctrl(reply_err("error", text)));
        Ok(())
    };
    let num = |k: &str| msg.get(k).and_then(|v| v.as_f64());
    let hex = |k: &str| {
        msg.get(k)
            .and_then(|v| v.as_str())
            .and_then(|s| u64::from_str_radix(s, 16).ok())
    };
    let (Some(key), Some(base)) = (hex("key"), hex("base")) else {
        return refuse("tp_hello: missing or malformed key/base".into());
    };
    let (Some(of), Some(rank), Some(rows), Some(n2), Some(sites)) = (
        num("of"),
        num("rank"),
        num("rows"),
        num("n2"),
        num("sites"),
    ) else {
        return refuse("tp_hello: missing of/rank/rows/n2/sites".into());
    };
    let (of, rank, rows, n2, sites) = (
        of as usize,
        rank as usize,
        rows as usize,
        n2 as usize,
        sites as usize,
    );
    if of < 2 || rank == 0 || rank >= of {
        return refuse(format!("tp_hello: rank {rank} of {of} is not a follower"));
    }
    if rows == 0 || n2 == 0 {
        return refuse("tp_hello: empty chunk schedule (rows and n2 must be > 0)".into());
    }
    if msg.get("compute").and_then(|v| v.as_str()) != Some("f32") {
        return refuse("tensor-parallel groups run f32 compute only".into());
    }
    let store = match svc.cache().get_by_key(key) {
        Ok((s, _)) => s,
        Err(e) => return refuse(e.to_string()),
    };
    let Some(shard) = store.shard.clone() else {
        return refuse(format!(
            "store {key:016x} is not a shard (this backend cannot follow a TP group with it)"
        ));
    };
    if shard.index != rank || shard.of != of || shard.base != base {
        return refuse(format!(
            "shard mismatch: leader wants rank {rank} of {of} (base {base:016x}), \
             this backend holds shard {} of {} (base {:016x})",
            shard.index, shard.of, shard.base
        ));
    }
    if store.spec.m() != sites || shard.full_bonds.len() != sites {
        return refuse(format!(
            "site count mismatch: group walks {sites} sites, shard store has {}",
            store.spec.m()
        ));
    }
    // Older leaders don't send a workload tag — they predate non-GBS
    // workloads, so an absent tag means GBS by construction.
    let leader_workload = msg
        .get("workload")
        .and_then(|v| v.as_str())
        .unwrap_or("gbs");
    if leader_workload != store.spec.tag() {
        return refuse(format!(
            "workload mismatch: leader runs {leader_workload:?}, \
             this backend's shard store is {:?}",
            store.spec.tag()
        ));
    }
    if store.spec.has_displacement() {
        return refuse("tensor-parallel jobs do not support displaced sampling".into());
    }
    // Fail the env broadcast size at the hello instead of mid-stream:
    // the leader's chunks must fit this server's frame cap.
    let chi_l_max = shard.full_bonds.iter().map(|b| b.0).max().unwrap_or(0);
    let env_frame = rows.min(n2) * chi_l_max * 8;
    if env_frame > net.max_frame_bytes {
        return refuse(format!(
            "env chunks of {env_frame} bytes exceed this server's {} byte frame cap \
             (raise net.max_frame_bytes or lower n2_micro on the leader)",
            net.max_frame_bytes
        ));
    }

    let job = num("job").map(|v| v as u64).unwrap_or(0);
    let trace = msg
        .get("trace")
        .and_then(|v| v.as_str())
        .and_then(crate::trace::parse_trace_id)
        .unwrap_or(0);
    tx.send(Out::Ctrl(reply_ok(
        "tp_welcome",
        vec![("rank", Json::Num(rank as f64))],
    )))
    .map_err(|_| Error::other("net: writer thread gone"))?;

    let cfg = svc.config();
    let step_timeout = Duration::from_millis(cfg.tp_step_timeout_ms.max(1));
    let prep = svc.cache().prepared(
        key,
        sites,
        PrepKey {
            compute: ComputePrecision::F32,
            gamma_f16: false,
            // Interleaved on purpose: TP tensors go over the wire.
            planar: false,
        },
        cfg.prep_cache_bytes,
    );
    let mut walk = SiteWalk::new(store.clone(), svc.cache().disk.clone(), prep);

    // Session-resident pool, like the leader's: parked workers live for
    // the whole TP session instead of spawning per chunk.
    let pool = WorkerPool::new(cfg.gemm_threads);
    let exec = Exec::Pooled(&pool);

    let t_group = Instant::now();
    let mut metrics = Metrics::new();
    let mut seq = 0u64;
    let mut wire: Vec<f32> = Vec::new();
    let mut part: Vec<f32> = Vec::new();
    let mut env_in: Mat<f32> = Mat::zeros(0, 0);
    let mut temp: Tensor3<f32> = Tensor3::zeros(0, 0, 0);

    // Receive the next TP frame, which must carry exactly (op, seq) —
    // the follower mirrors SocketComm's per-collective sequence count.
    let recv_tp = |reader: &mut FrameReader<BufReader<TcpStream>>,
                       op: u8,
                       seq: u64,
                       out: &mut Vec<f32>|
     -> Result<u64> {
        out.clear();
        let deadline = Instant::now() + step_timeout;
        loop {
            if stop.load(Ordering::Relaxed) {
                return Err(Error::other("server stopping mid TP group"));
            }
            match reader.read_frame_idle()? {
                None => {
                    if Instant::now() >= deadline {
                        return Err(Error::Fabric(format!(
                            "TP leader sent nothing for {}ms awaiting {}",
                            step_timeout.as_millis(),
                            tp_op_name(op)
                        )));
                    }
                }
                Some(Frame::Tp(p)) => {
                    let (got_op, got_seq) = frame::decode_tp_into(&p, out)?;
                    if (got_op, got_seq) != (op, seq) {
                        return Err(Error::Fabric(format!(
                            "TP desync with leader: got ({}, seq {got_seq}), want ({}, seq {seq})",
                            tp_op_name(got_op),
                            tp_op_name(op)
                        )));
                    }
                    return Ok((out.len() * 4) as u64);
                }
                Some(Frame::Ctrl(_)) => {
                    return Err(Error::Fabric(
                        "control frame mid TP group (the leader lost the session plot)".into(),
                    ));
                }
                Some(_) => {
                    return Err(Error::Fabric("non-TP frame mid TP group".into()));
                }
            }
        }
    };

    let outcome = (|| -> Result<()> {
        for site_idx in 0..sites {
            let psite = walk.site(site_idx, &mut metrics)?;
            let gamma = f32_gamma(&psite)?;
            let chi_l = shard.full_bonds[site_idx].0;
            if gamma.d0 != chi_l {
                return Err(Error::format(format!(
                    "shard site {site_idx} has χ_l {}, manifest promises {chi_l}",
                    gamma.d0
                )));
            }
            let mut off = 0usize;
            while off < rows {
                let take = (rows - off).min(n2);
                seq += 1;
                let got = recv_tp(reader, TP_ENV, seq, &mut wire)?;
                metrics.add(keys::TP_BCAST_BYTES, got);
                wire_to_mat(&wire, take, chi_l, &mut env_in)?;
                let t0 = Instant::now();
                contract_env_into_on(&env_in, gamma, &mut temp, exec, cfg.gemm_split)?;
                metrics.add_phase("compute", t0.elapsed().as_secs_f64());
                metrics.add(
                    keys::FLOPS,
                    matmul_flops(take, gamma.d0, gamma.d1 * gamma.d2),
                );
                complexes_to_wire(&temp.data, &mut part);
                seq += 1;
                tx.send(Out::Tp(frame::encode_tp(TP_PART, seq, &part)))
                    .map_err(|_| Error::other("net: writer thread gone"))?;
                metrics.add(keys::TP_REDUCE_BYTES, (part.len() * 4) as u64);
                // Outcome broadcast: lockstep participation only — the
                // follower holds no environment to advance.
                seq += 1;
                let got = recv_tp(reader, TP_OUTCOME, seq, &mut wire)?;
                metrics.add(keys::TP_BCAST_BYTES, got);
                off += take;
            }
        }
        seq += 1;
        recv_tp(reader, TP_DONE, seq, &mut wire)?;
        Ok(())
    })();
    if let Err(e) = outcome {
        metrics.add(keys::TP_JOBS, 1);
        metrics.add(keys::TP_MEMBER_FAILURES, 1);
        svc.merge_metrics(&metrics);
        return Err(e);
    }
    walk.finish(&mut metrics)?;
    let (wakeups, park_ns) = pool.take_counters();
    metrics.add(keys::POOL_WAKEUPS, wakeups);
    metrics.add(keys::POOL_PARK_NS, park_ns);
    metrics.add(keys::TP_JOBS, 1);
    svc.merge_metrics(&metrics);
    svc.recorder().span(
        Layer::Tp,
        "tp_follow",
        job,
        trace,
        t_group.elapsed().as_nanos() as u64,
        rank as u64,
    );
    tx.send(Out::Ctrl(reply_ok("tp_done", vec![])))
        .map_err(|_| Error::other("net: writer thread gone"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::GemmSplit;
    use crate::rng::Xoshiro256;

    fn random_complex(rng: &mut Xoshiro256, n: usize) -> Vec<Complex<f32>> {
        (0..n)
            .map(|_| Complex::new(rng.normal() as f32, rng.normal() as f32))
            .collect()
    }

    #[test]
    fn wire_roundtrip_preserves_every_bit() {
        let mut rng = Xoshiro256::seed_from(11);
        let data = random_complex(&mut rng, 6 * 4);
        let m = Mat::from_vec(6, 4, data.clone()).unwrap();
        let mut wire = Vec::new();
        complexes_to_wire(&m.data, &mut wire);
        assert_eq!(wire.len(), 48);
        let mut back: Mat<f32> = Mat::zeros(0, 0);
        wire_to_mat(&wire, 6, 4, &mut back).unwrap();
        assert_eq!(back.data, m.data);
        assert!(wire_to_mat(&wire, 5, 4, &mut back).is_err(), "ragged shape");
    }

    #[test]
    fn sharded_contraction_assembles_bit_identically() {
        // Full contraction vs per-shard contraction + assemble_temp: the
        // disjoint-column design means not one ulp may differ.
        let mut rng = Xoshiro256::seed_from(12);
        let (n, chi_l, chi_r, d, of) = (5, 7, 9, 3, 3);
        let env = Mat::from_vec(n, chi_l, random_complex(&mut rng, n * chi_l)).unwrap();
        let full =
            Tensor3::from_vec(chi_l, chi_r, d, random_complex(&mut rng, chi_l * chi_r * d))
                .unwrap();
        let mut want = Tensor3::zeros(0, 0, 0);
        contract_env_into(&env, &full, &mut want, 1, GemmSplit::Auto).unwrap();

        // Contract each column shard independently, concat rank-order.
        let mut gathered: Vec<f32> = Vec::new();
        for k in 0..of {
            let (lo, hi) = shard_range(chi_r, k, of);
            let mut shard_data = Vec::new();
            for x in 0..chi_l {
                for y in lo..hi {
                    for p in 0..d {
                        shard_data.push(full.at(x, y, p));
                    }
                }
            }
            let shard = Tensor3::from_vec(chi_l, hi - lo, d, shard_data).unwrap();
            let mut part = Tensor3::zeros(0, 0, 0);
            contract_env_into(&env, &shard, &mut part, 1, GemmSplit::Auto).unwrap();
            let mut w = Vec::new();
            complexes_to_wire(&part.data, &mut w);
            gathered.extend_from_slice(&w);
        }
        let mut got = Tensor3::zeros(0, 0, 0);
        assemble_temp(&gathered, n, d, chi_r, of, &mut got).unwrap();
        assert_eq!(got.data, want.data, "sharded == full, bitwise");

        // A short gather is a typed error, not a silent partial tensor.
        gathered.pop();
        assert!(assemble_temp(&gathered, n, d, chi_r, of, &mut got).is_err());
    }

    #[test]
    fn fmpn_link_speaks_the_group_protocol() {
        // Loopback follower: preamble exchange, hello/welcome, one
        // bcast+gather round, teardown ack — the full link lifecycle.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let follower = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut w = FrameWriter::new(BufWriter::new(stream.try_clone().unwrap()));
            let mut r = FrameReader::new(BufReader::new(stream), 1 << 20);
            w.write_preamble().unwrap();
            r.read_preamble().unwrap();
            let hello = match r.read_frame().unwrap() {
                Frame::Ctrl(j) => j,
                _ => panic!("expected hello"),
            };
            assert_eq!(hello.get("op").and_then(|v| v.as_str()), Some("tp_hello"));
            assert_eq!(hello.get("rank").and_then(|v| v.as_f64()), Some(1.0));
            w.write_ctrl(&reply_ok("tp_welcome", vec![])).unwrap();
            // One collective round: env in, doubled floats out.
            let mut buf = Vec::new();
            let (op, seq) = match r.read_frame().unwrap() {
                Frame::Tp(p) => frame::decode_tp_into(&p, &mut buf).unwrap(),
                _ => panic!("expected TP frame"),
            };
            assert_eq!((op, seq), (TP_ENV, 1));
            let doubled: Vec<f32> = buf.iter().map(|v| v * 2.0).collect();
            w.write_tp(&frame::encode_tp(TP_PART, 2, &doubled)).unwrap();
            // Teardown: TP_DONE then the final control acknowledgement.
            buf.clear();
            let (op, seq) = match r.read_frame().unwrap() {
                Frame::Tp(p) => frame::decode_tp_into(&p, &mut buf).unwrap(),
                _ => panic!("expected TP_DONE"),
            };
            assert_eq!((op, seq), (TP_DONE, 3));
            w.write_ctrl(&reply_ok("tp_done", vec![])).unwrap();
        });

        let hello = Json::obj(vec![
            ("op", Json::Str("tp_hello".into())),
            ("rank", Json::Num(1.0)),
        ]);
        let link = FmpnLink::dial(&addr, &hello, 5000, 1 << 20).unwrap();
        let mut comm = SocketComm::new(0, vec![None, Some(Box::new(link))]).unwrap();
        let mut env = vec![1.5f32, -2.0, 0.25];
        comm.bcast(TP_ENV, &mut env, 0).unwrap();
        let mut gathered = Vec::new();
        comm.gather(TP_PART, &[9.0f32], &mut gathered, 0).unwrap();
        assert_eq!(gathered, vec![9.0, 3.0, -4.0, 0.5], "rank order: mine, then peer");
        let mut none = Vec::new();
        comm.bcast(TP_DONE, &mut none, 0).unwrap();
        comm.finish().unwrap();
        follower.join().unwrap();
    }
}
