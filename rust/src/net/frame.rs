//! The FMPN wire format: preamble, varint-framed messages, sink payloads.
//!
//! A connection starts with a 5-byte preamble in each direction (4-byte
//! magic `FMPN` + 1-byte protocol version); both sides send eagerly and
//! validate what the peer sent, so the handshake cannot deadlock. After
//! the preamble the stream is a sequence of frames:
//!
//! ```text
//! frame := type:u8 | len:varint(LEB128) | payload[len]
//! ```
//!
//! Frame types in version 1:
//! - [`FRAME_CTRL`] — one NDJSON control message (a single JSON object,
//!   UTF-8; see `docs/PROTOCOL.md` for the op vocabulary);
//! - [`FRAME_PAYLOAD`] — a binary sample block: an encoded [`SampleSink`]
//!   run through `util::compress`, so results stream back without
//!   JSON-escaping tensors;
//! - [`FRAME_CHUNK`] — one chunk of a store push;
//! - [`FRAME_TP`] — one tensor-parallel data-plane message (a collective
//!   op byte + sequence number + raw little-endian f32 payload; see
//!   `docs/TENSOR_PARALLEL.md`). Builds that predate TP reject the type
//!   with a typed "unknown frame type" error — never a hang — but TP
//!   frames only ever follow a `tp_hello` the peer already accepted.
//!
//! Readers enforce a frame-size cap (`NetConfig::max_frame_bytes`) before
//! allocating, and every decode validates lengths, so a corrupt or
//! malicious stream errors instead of exhausting memory or panicking.

use std::io::{ErrorKind, Read, Write};

use crate::sampler::sink::SampleSink;
use crate::util::compress;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Wire magic: "FastMPS Net".
pub const MAGIC: [u8; 4] = *b"FMPN";
/// Protocol version this build speaks.
pub const VERSION: u8 = 1;

/// Frame type: NDJSON control message.
pub const FRAME_CTRL: u8 = 1;
/// Frame type: binary sample-block payload.
pub const FRAME_PAYLOAD: u8 = 2;
/// Frame type: one chunk of a store push (`push_begin` … `push_end`);
/// see [`encode_chunk`] and `docs/PROTOCOL.md` § Chunked store push.
pub const FRAME_CHUNK: u8 = 3;
/// Frame type: one tensor-parallel collective message (`tp_hello` …
/// `tp_done`); see [`encode_tp`] and `docs/PROTOCOL.md` § Tensor-parallel
/// data plane.
pub const FRAME_TP: u8 = 4;

/// A decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// One JSON control message.
    Ctrl(Json),
    /// One compressed sample block (still packed; see [`unpack_sink`]).
    Payload(Vec<u8>),
    /// One store-push chunk (still packed; see [`decode_chunk`]).
    Chunk(Vec<u8>),
    /// One TP collective message (still packed; see [`decode_tp_into`]).
    Tp(Vec<u8>),
}

fn wire_err(msg: impl std::fmt::Display) -> Error {
    Error::Format(format!("net wire: {msg}"))
}

fn io_wire(ctx: &str, e: std::io::Error) -> Error {
    Error::io(format!("net wire ({ctx})"), e)
}

/// True when an I/O error is a read timeout (idle socket), not a failure.
pub fn is_timeout(e: &Error) -> bool {
    match e {
        Error::Io { source, .. } => {
            matches!(source.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
        }
        _ => false,
    }
}

/// Send our preamble (magic + version).
pub fn write_preamble<W: Write>(w: &mut W) -> Result<()> {
    w.write_all(&MAGIC).map_err(|e| io_wire("preamble", e))?;
    w.write_all(&[VERSION]).map_err(|e| io_wire("preamble", e))?;
    w.flush().map_err(|e| io_wire("preamble", e))
}

/// Read and validate the peer's preamble; returns its version.
pub fn read_preamble<R: Read>(r: &mut R) -> Result<u8> {
    let mut buf = [0u8; 5];
    r.read_exact(&mut buf).map_err(|e| io_wire("preamble", e))?;
    if buf[..4] != MAGIC {
        return Err(wire_err(format!(
            "bad magic {:02x}{:02x}{:02x}{:02x} (not an FMPN endpoint)",
            buf[0], buf[1], buf[2], buf[3]
        )));
    }
    if buf[4] != VERSION {
        return Err(wire_err(format!(
            "peer speaks protocol version {}, this build speaks {VERSION}",
            buf[4]
        )));
    }
    Ok(buf[4])
}

/// LEB128-encode `v` into `out` (the same codec `util::compress` frames
/// its blobs with — one implementation, shared).
pub fn push_varint(out: &mut Vec<u8>, v: u64) {
    compress::write_varint(out, v);
}

/// Decode a LEB128 varint from `b[*i]..`, advancing `i`. A cursor past
/// the end of `b` is a hard decode error — never clamped: a caller whose
/// cursor ran off the buffer has already lost sync, and silently reading
/// "from the end" would let it advance further still.
pub fn take_varint(b: &[u8], i: &mut usize) -> Result<u64> {
    if *i > b.len() {
        return Err(wire_err(format!(
            "varint cursor {} beyond buffer of {} bytes",
            *i,
            b.len()
        )));
    }
    let (v, n) = compress::read_varint(&b[*i..]).map_err(wire_err)?;
    *i += n;
    Ok(v)
}

fn read_varint_stream<R: Read>(r: &mut R) -> Result<(u64, usize)> {
    let mut v = 0u64;
    let mut shift = 0u32;
    let mut n = 0usize;
    loop {
        let mut byte = [0u8; 1];
        r.read_exact(&mut byte).map_err(|e| io_wire("frame length", e))?;
        n += 1;
        if shift >= 64 {
            return Err(wire_err("frame length varint overflow"));
        }
        v |= u64::from(byte[0] & 0x7f) << shift;
        if byte[0] & 0x80 == 0 {
            return Ok((v, n));
        }
        shift += 7;
    }
}

/// Serializing side of a connection. Tracks bytes/frames written so the
/// owner can fold them into the net metrics.
pub struct FrameWriter<W: Write> {
    w: W,
    bytes: u64,
    frames: u64,
}

impl<W: Write> FrameWriter<W> {
    pub fn new(w: W) -> FrameWriter<W> {
        FrameWriter {
            w,
            bytes: 0,
            frames: 0,
        }
    }

    fn write_frame(&mut self, kind: u8, payload: &[u8]) -> Result<()> {
        let mut head = Vec::with_capacity(11);
        head.push(kind);
        push_varint(&mut head, payload.len() as u64);
        self.w.write_all(&head).map_err(|e| io_wire("frame header", e))?;
        self.w.write_all(payload).map_err(|e| io_wire("frame payload", e))?;
        self.w.flush().map_err(|e| io_wire("frame flush", e))?;
        self.bytes += (head.len() + payload.len()) as u64;
        self.frames += 1;
        Ok(())
    }

    /// Send our preamble through this writer (raw bytes, not a frame).
    pub fn write_preamble(&mut self) -> Result<()> {
        write_preamble(&mut self.w)?;
        self.bytes += 5;
        Ok(())
    }

    /// Send one NDJSON control message.
    pub fn write_ctrl(&mut self, msg: &Json) -> Result<()> {
        self.write_frame(FRAME_CTRL, msg.dump().as_bytes())
    }

    /// Send one binary payload block (already packed).
    pub fn write_payload(&mut self, packed: &[u8]) -> Result<()> {
        self.write_frame(FRAME_PAYLOAD, packed)
    }

    /// Send one store-push chunk (already packed; see [`encode_chunk`]).
    pub fn write_chunk(&mut self, packed: &[u8]) -> Result<()> {
        self.write_frame(FRAME_CHUNK, packed)
    }

    /// Send one TP collective message (already packed; see [`encode_tp`]).
    pub fn write_tp(&mut self, packed: &[u8]) -> Result<()> {
        self.write_frame(FRAME_TP, packed)
    }

    /// Return and reset the (bytes, frames) written since the last call.
    pub fn drain_counters(&mut self) -> (u64, u64) {
        let out = (self.bytes, self.frames);
        self.bytes = 0;
        self.frames = 0;
        out
    }
}

/// Deserializing side of a connection, with a frame-size cap.
pub struct FrameReader<R: Read> {
    r: R,
    max_frame: usize,
    bytes: u64,
    frames: u64,
}

impl<R: Read> FrameReader<R> {
    pub fn new(r: R, max_frame: usize) -> FrameReader<R> {
        FrameReader {
            r,
            max_frame: max_frame.max(64),
            bytes: 0,
            frames: 0,
        }
    }

    /// Read and validate the peer's preamble through this reader.
    pub fn read_preamble(&mut self) -> Result<u8> {
        let v = read_preamble(&mut self.r)?;
        self.bytes += 5;
        Ok(v)
    }

    /// Blocking read of the next frame. Errors on EOF, timeout, cap
    /// violation, or malformed content.
    pub fn read_frame(&mut self) -> Result<Frame> {
        let mut kind = [0u8; 1];
        self.r.read_exact(&mut kind).map_err(|e| io_wire("frame type", e))?;
        self.read_frame_body(kind[0])
    }

    /// Like [`read_frame`](Self::read_frame), but a read timeout *before
    /// the first byte* of a frame returns `Ok(None)` (idle connection) so
    /// server loops can poll their stop flag. A timeout mid-frame is still
    /// an error — the stream would be out of sync.
    pub fn read_frame_idle(&mut self) -> Result<Option<Frame>> {
        let mut kind = [0u8; 1];
        match self.r.read_exact(&mut kind) {
            Ok(()) => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return Ok(None);
            }
            Err(e) => return Err(io_wire("frame type", e)),
        }
        self.read_frame_body(kind[0]).map(Some)
    }

    fn read_frame_body(&mut self, kind: u8) -> Result<Frame> {
        let (len, len_bytes) = read_varint_stream(&mut self.r)?;
        let len = usize::try_from(len).map_err(|_| wire_err("frame length overflow"))?;
        if len > self.max_frame {
            return Err(wire_err(format!(
                "frame of {len} bytes exceeds the {} byte cap",
                self.max_frame
            )));
        }
        let mut payload = vec![0u8; len];
        self.r
            .read_exact(&mut payload)
            .map_err(|e| io_wire("frame payload", e))?;
        self.bytes += (1 + len_bytes + len) as u64;
        self.frames += 1;
        match kind {
            FRAME_CTRL => {
                let text = std::str::from_utf8(&payload)
                    .map_err(|_| wire_err("control frame is not UTF-8"))?;
                Ok(Frame::Ctrl(Json::parse(text.trim_end_matches('\n'))?))
            }
            FRAME_PAYLOAD => Ok(Frame::Payload(payload)),
            FRAME_CHUNK => Ok(Frame::Chunk(payload)),
            FRAME_TP => Ok(Frame::Tp(payload)),
            other => Err(wire_err(format!("unknown frame type 0x{other:02x}"))),
        }
    }

    /// Return and reset the (bytes, frames) read since the last call.
    pub fn drain_counters(&mut self) -> (u64, u64) {
        let out = (self.bytes, self.frames);
        self.bytes = 0;
        self.frames = 0;
        out
    }
}

/// Raw (uncompressed) binary encoding of a [`SampleSink`]:
///
/// ```text
/// sink := varint m | varint d | varint max_gap
///       | m*d varints                    (hist, site-major)
///       | m varints                      (counts)
///       | (m-1)*max(max_gap,1) f64-le    (pair_sums; SampleSink::pair_sum_len)
/// ```
pub fn encode_sink(s: &SampleSink) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + s.m * s.d * 2 + s.pair_sums.len() * 8);
    push_varint(&mut out, s.m as u64);
    push_varint(&mut out, s.d as u64);
    push_varint(&mut out, s.max_gap as u64);
    for site in &s.hist {
        for &c in site {
            push_varint(&mut out, c);
        }
    }
    for &c in &s.counts {
        push_varint(&mut out, c);
    }
    for &p in &s.pair_sums {
        out.extend_from_slice(&p.to_le_bytes());
    }
    out
}

/// Inverse of [`encode_sink`]; validates every length.
pub fn decode_sink(b: &[u8]) -> Result<SampleSink> {
    let mut i = 0usize;
    let m = take_varint(b, &mut i)? as usize;
    let d = take_varint(b, &mut i)? as usize;
    let max_gap = take_varint(b, &mut i)? as usize;
    // A sink this code ever puts on the wire is store-shaped; reject
    // absurd headers before allocating m*d vectors.
    if m > 1 << 20 || d > 1 << 16 || max_gap > 1 << 16 {
        return Err(wire_err(format!(
            "implausible sink header m={m} d={d} max_gap={max_gap}"
        )));
    }
    if m == 0 || d == 0 {
        return Err(wire_err("sink header has zero dimension"));
    }
    // The header is untrusted: a varint is ≥ 1 byte and a pair sum is 8,
    // so the smallest stream this header could describe is bounded below.
    // Reject claims the buffer cannot possibly satisfy BEFORE allocating
    // (the per-dimension caps above still admit ~512 GiB of hist). The
    // pair-sum count comes from the sink's own allocation rule so the
    // bound can never drift from what `SampleSink::new` (and hence
    // `encode_sink`) actually puts on the wire — in particular a
    // `max_gap == 0` sink still carries `m - 1` pair sums.
    let min_need = (m as u64) * (d as u64)
        + m as u64
        + 8 * SampleSink::pair_sum_len(m, max_gap) as u64;
    if min_need > b.len() as u64 {
        return Err(wire_err(format!(
            "sink header needs ≥ {min_need} bytes, buffer has {}",
            b.len()
        )));
    }
    let mut sink = SampleSink::new(m, d, max_gap);
    for site in sink.hist.iter_mut() {
        for c in site.iter_mut() {
            *c = take_varint(b, &mut i)?;
        }
    }
    for c in sink.counts.iter_mut() {
        *c = take_varint(b, &mut i)?;
    }
    for p in sink.pair_sums.iter_mut() {
        let bytes: [u8; 8] = b
            .get(i..i + 8)
            .ok_or_else(|| wire_err("truncated pair_sums"))?
            .try_into()
            .unwrap();
        *p = f64::from_le_bytes(bytes);
        i += 8;
    }
    if i != b.len() {
        return Err(wire_err(format!("{} trailing bytes after sink", b.len() - i)));
    }
    Ok(sink)
}

/// Encode + compress a sink for a payload frame.
pub fn pack_sink(s: &SampleSink) -> Vec<u8> {
    compress::compress(&encode_sink(s))
}

/// Decompress + decode a payload frame into a sink.
pub fn unpack_sink(packed: &[u8]) -> Result<SampleSink> {
    let raw = compress::decompress(packed).map_err(wire_err)?;
    decode_sink(&raw)
}

/// Encode one store-push chunk for a [`FRAME_CHUNK`] frame:
///
/// ```text
/// chunk := varint index          # 0-based position in the push
///        | fnv:u64-le            # running FNV-1a of ALL raw bytes so far
///        | lz(raw)               # this chunk, independently compressed
/// ```
///
/// The running checksum chains chunks together, so a dropped, duplicated,
/// or reordered chunk is detected at the first affected chunk rather than
/// only at `push_end`.
pub fn encode_chunk(index: u64, running_fnv: u64, raw: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(raw.len() / 2 + 16);
    push_varint(&mut out, index);
    out.extend_from_slice(&running_fnv.to_le_bytes());
    out.extend_from_slice(&compress::compress(raw));
    out
}

/// Encode one TP collective message for a [`FRAME_TP`] frame:
///
/// ```text
/// tp := op:u8               # TP_ENV / TP_PART / TP_OUTCOME / TP_DONE
///     | varint seq          # per-link collective sequence number
///     | n × f32-le          # payload (may be empty, e.g. TP_DONE or a
///                           #   zero-width shard's partial)
/// ```
///
/// TP payloads are NOT compressed: they are dense f32 environments and
/// partial contractions mid-hot-loop, where LZ rarely wins and the extra
/// copy would dominate. The sequence number is checked by the receiver so
/// a desynchronised group fails with a typed error instead of silently
/// reducing the wrong site's data.
pub fn encode_tp(op: u8, seq: u64, data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(11 + data.len() * 4);
    out.push(op);
    push_varint(&mut out, seq);
    for &x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Inverse of [`encode_tp`]: appends the f32 payload to `out` (so a TP
/// hot loop can reuse one buffer) and returns `(op, seq)`.
pub fn decode_tp_into(packed: &[u8], out: &mut Vec<f32>) -> Result<(u8, u64)> {
    if packed.is_empty() {
        return Err(wire_err("empty TP frame"));
    }
    let op = packed[0];
    let mut i = 1usize;
    let seq = take_varint(packed, &mut i)?;
    let body = &packed[i..];
    if body.len() % 4 != 0 {
        return Err(wire_err(format!(
            "TP frame body of {} bytes is not a whole number of f32s",
            body.len()
        )));
    }
    out.reserve(body.len() / 4);
    for chunk in body.chunks_exact(4) {
        out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok((op, seq))
}

/// Inverse of [`encode_chunk`]: `(index, running_fnv, raw_bytes)`.
pub fn decode_chunk(packed: &[u8]) -> Result<(u64, u64, Vec<u8>)> {
    let mut i = 0usize;
    let index = take_varint(packed, &mut i)?;
    let fnv_bytes: [u8; 8] = packed
        .get(i..i + 8)
        .ok_or_else(|| wire_err("truncated chunk checksum"))?
        .try_into()
        .unwrap();
    i += 8;
    let running_fnv = u64::from_le_bytes(fnv_bytes);
    let raw = compress::decompress(&packed[i..]).map_err(wire_err)?;
    Ok((index, running_fnv, raw))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_sink() -> SampleSink {
        let mut s = SampleSink::new(4, 3, 2);
        s.reset_walk();
        s.record(0, &[0, 1, 2]);
        s.record(1, &[2, 2, 1]);
        s.record(2, &[1, 0, 0]);
        s.record(3, &[0, 0, 2]);
        s
    }

    #[test]
    fn varint_roundtrip_boundaries() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            push_varint(&mut buf, v);
            let mut i = 0;
            assert_eq!(take_varint(&buf, &mut i).unwrap(), v);
            assert_eq!(i, buf.len());
        }
        let mut i = 0;
        assert!(take_varint(&[0x80], &mut i).is_err(), "truncated");
        let mut i = 0;
        assert!(
            take_varint(&[0xff; 11], &mut i).is_err(),
            "overlong varint rejected"
        );
        // Invariant: a cursor beyond the buffer is a hard decode error —
        // not clamped to the end — and must not advance.
        let buf = [0x01u8, 0x02];
        let mut i = buf.len(); // exactly at the end: empty read, clean error
        assert!(take_varint(&buf, &mut i).is_err(), "cursor at end");
        assert_eq!(i, buf.len(), "cursor unchanged on error");
        let mut i = buf.len() + 3; // beyond the end: must error, never wrap
        let e = take_varint(&buf, &mut i).unwrap_err().to_string();
        assert!(e.contains("beyond buffer"), "{e}");
        assert_eq!(i, buf.len() + 3, "cursor unchanged on error");
    }

    #[test]
    fn preamble_roundtrip_and_rejections() {
        let mut buf = Vec::new();
        write_preamble(&mut buf).unwrap();
        assert_eq!(read_preamble(&mut buf.as_slice()).unwrap(), VERSION);

        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(read_preamble(&mut bad.as_slice()).is_err(), "bad magic");
        let mut newer = buf.clone();
        newer[4] = VERSION + 1;
        let e = read_preamble(&mut newer.as_slice()).unwrap_err().to_string();
        assert!(e.contains("version"), "{e}");
        assert!(read_preamble(&mut &buf[..3]).is_err(), "short preamble");
    }

    #[test]
    fn frame_roundtrip_ctrl_and_payload() {
        let msg = Json::obj(vec![
            ("op", Json::Str("submit".into())),
            ("id", Json::Num(7.0)),
        ]);
        let mut buf = Vec::new();
        let mut w = FrameWriter::new(&mut buf);
        w.write_ctrl(&msg).unwrap();
        w.write_payload(b"\x01\x02\x03").unwrap();
        let (bytes, frames) = w.drain_counters();
        assert_eq!(frames, 2);
        assert_eq!(bytes as usize, buf.len());

        let mut r = FrameReader::new(buf.as_slice(), 1 << 20);
        assert_eq!(r.read_frame().unwrap(), Frame::Ctrl(msg));
        assert_eq!(r.read_frame().unwrap(), Frame::Payload(vec![1, 2, 3]));
        let (rbytes, rframes) = r.drain_counters();
        assert_eq!((rbytes as usize, rframes), (buf.len(), 2));
        assert!(r.read_frame().is_err(), "EOF is an error");
    }

    #[test]
    fn frame_cap_and_corruption_rejected() {
        let mut buf = Vec::new();
        let mut w = FrameWriter::new(&mut buf);
        w.write_payload(&[0u8; 4096]).unwrap();
        let mut r = FrameReader::new(buf.as_slice(), 1024);
        let e = r.read_frame().unwrap_err().to_string();
        assert!(e.contains("cap"), "{e}");

        // Unknown frame type.
        let mut junk = vec![0x7fu8];
        push_varint(&mut junk, 0);
        assert!(FrameReader::new(junk.as_slice(), 1024).read_frame().is_err());

        // Control frame with broken JSON.
        let mut bad = vec![FRAME_CTRL];
        push_varint(&mut bad, 2);
        bad.extend_from_slice(b"{n");
        assert!(FrameReader::new(bad.as_slice(), 1024).read_frame().is_err());

        // Truncated payload.
        let mut short = vec![FRAME_PAYLOAD];
        push_varint(&mut short, 10);
        short.extend_from_slice(b"abc");
        assert!(FrameReader::new(short.as_slice(), 1024).read_frame().is_err());
    }

    #[test]
    fn sink_roundtrips_exactly() {
        let s = sample_sink();
        let packed = pack_sink(&s);
        let back = unpack_sink(&packed).unwrap();
        assert_eq!(back.m, s.m);
        assert_eq!(back.d, s.d);
        assert_eq!(back.max_gap, s.max_gap);
        assert_eq!(back.hist, s.hist);
        assert_eq!(back.counts, s.counts);
        assert_eq!(back.pair_sums, s.pair_sums);
    }

    #[test]
    fn max_gap_zero_sink_roundtrips_and_its_bytes_are_counted() {
        // A max_gap == 0 sink still allocates (m-1) pair sums
        // (`SampleSink::pair_sum_len`); they transit the wire and the
        // decoder's pre-allocation bound must count them.
        let mut s = SampleSink::new(4, 3, 0);
        s.reset_walk();
        for site in 0..4 {
            s.record(site, &[0, 2, 1]);
        }
        let back = unpack_sink(&pack_sink(&s)).unwrap();
        assert_eq!(back.max_gap, 0);
        assert_eq!(back.hist, s.hist);
        assert_eq!(back.counts, s.counts);
        assert_eq!(back.pair_sums, s.pair_sums);
        assert_eq!(back.pair_sums.len(), SampleSink::pair_sum_len(4, 0));

        // Regression: a header claiming m=4 d=1 max_gap=0 describes ≥
        // 4 + 4 + 8·3 = 32 bytes. The old bound ignored the pair sums
        // (8·(m-1)·max_gap = 0) and let a 20-byte buffer through to the
        // slow path; the shared-helper bound must reject it up front.
        let mut short = Vec::new();
        push_varint(&mut short, 4);
        push_varint(&mut short, 1);
        push_varint(&mut short, 0);
        short.resize(20, 0);
        let e = decode_sink(&short).unwrap_err().to_string();
        assert!(e.contains("needs ≥"), "bound check must fire first: {e}");
    }

    #[test]
    fn chunk_roundtrip_and_corruption() {
        let raw: Vec<u8> = (0..5000).map(|i| ((i / 3) % 251) as u8).collect();
        let packed = encode_chunk(7, 0xdead_beef_cafe_f00d, &raw);
        let (index, fnv, back) = decode_chunk(&packed).unwrap();
        assert_eq!(index, 7);
        assert_eq!(fnv, 0xdead_beef_cafe_f00d);
        assert_eq!(back, raw);

        // Chunk frames transit the frame layer like any other type.
        let mut buf = Vec::new();
        let mut w = FrameWriter::new(&mut buf);
        w.write_chunk(&packed).unwrap();
        let mut r = FrameReader::new(buf.as_slice(), 1 << 20);
        assert_eq!(r.read_frame().unwrap(), Frame::Chunk(packed.clone()));

        // Truncations error instead of panicking.
        assert!(decode_chunk(&packed[..4]).is_err(), "truncated checksum");
        assert!(decode_chunk(&[]).is_err(), "empty chunk");
        assert!(
            decode_chunk(&packed[..packed.len() - 3]).is_err(),
            "truncated body"
        );
    }

    #[test]
    fn tp_roundtrip_and_corruption() {
        let data = [1.0f32, -0.5, 3.25e-7, f32::MIN_POSITIVE, 0.0];
        let packed = encode_tp(2, 301, &data);
        let mut out = vec![9.0f32]; // decode appends, preserving prior content
        let (op, seq) = decode_tp_into(&packed, &mut out).unwrap();
        assert_eq!((op, seq), (2, 301));
        assert_eq!(out[0], 9.0);
        assert_eq!(&out[1..], &data, "payload is bit-exact LE f32");

        // Empty payload (TP_DONE, zero-width shard) is legal.
        let empty = encode_tp(4, 0, &[]);
        let mut out = Vec::new();
        assert_eq!(decode_tp_into(&empty, &mut out).unwrap(), (4, 0));
        assert!(out.is_empty());

        // TP frames transit the frame layer like any other type.
        let mut buf = Vec::new();
        let mut w = FrameWriter::new(&mut buf);
        w.write_tp(&packed).unwrap();
        let mut r = FrameReader::new(buf.as_slice(), 1 << 20);
        assert_eq!(r.read_frame().unwrap(), Frame::Tp(packed.clone()));

        // Corruption: empty frame, ragged body, truncated seq varint.
        let mut sink = Vec::new();
        assert!(decode_tp_into(&[], &mut sink).is_err(), "empty TP frame");
        let e = decode_tp_into(&packed[..packed.len() - 1], &mut sink)
            .unwrap_err()
            .to_string();
        assert!(e.contains("whole number of f32s"), "{e}");
        assert!(decode_tp_into(&[2, 0x80], &mut sink).is_err(), "bad seq");
    }

    #[test]
    fn sink_decode_rejects_corruption() {
        let raw = encode_sink(&sample_sink());
        assert!(decode_sink(&raw[..raw.len() - 4]).is_err(), "truncated");
        let mut trailing = raw.clone();
        trailing.push(0);
        assert!(decode_sink(&trailing).is_err(), "trailing bytes");
        // Implausible header must not allocate terabytes.
        let mut huge = Vec::new();
        push_varint(&mut huge, u64::MAX / 4);
        push_varint(&mut huge, 3);
        push_varint(&mut huge, 1);
        assert!(decode_sink(&huge).is_err());
        // Zero-dimension header.
        let mut zero = Vec::new();
        push_varint(&mut zero, 0);
        push_varint(&mut zero, 3);
        push_varint(&mut zero, 1);
        assert!(decode_sink(&zero).is_err());
        // Packed stream with flipped bytes must error, not panic.
        let packed = pack_sink(&sample_sink());
        for flip in [0usize, packed.len() / 2, packed.len() - 1] {
            let mut c = packed.clone();
            c[flip] ^= 0xa5;
            let _ = unpack_sink(&c); // must not panic; Err or (rarely) Ok
        }
        assert!(unpack_sink(&packed[..packed.len() - 2]).is_err());
    }
}
