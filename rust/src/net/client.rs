//! Blocking client for the FMPN protocol: connect / submit / wait /
//! stream. Used by the CLI (`--connect`) and the integration tests;
//! embeddable anywhere a `std::net::TcpStream` can reach a server.
//!
//! Requests on one connection are strictly sequential (send a control
//! frame, read the reply, optionally read a payload frame), so a single
//! `Client` is `&mut self` throughout and needs no internal locking.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::frame::{self, Frame, FrameReader, FrameWriter};
use crate::config::NetConfig;
use crate::metrics::HistogramStats;
use crate::sampler::sink::SampleSink;
use crate::service::{JobId, JobSpec};
use crate::trace::{Layer, Recorder};
use crate::util::backoff::Backoff;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// A terminal job's result: the JSON summary and, when the server has
/// sample statistics for the job, the decoded [`SampleSink`].
#[derive(Debug, Clone)]
pub struct JobResult {
    pub result: Json,
    pub sink: Option<SampleSink>,
}

/// Outcome of [`Client::push_store`].
#[derive(Debug, Clone)]
pub struct PushReport {
    /// Content key (manifest hash) — submit jobs with [`JobSpec::by_key`].
    pub key: u64,
    /// The receiver already had the store; nothing was transferred.
    pub dedup: bool,
    /// Chunks sent (0 on dedup).
    pub chunks: u64,
    /// Raw stream bytes sent (0 on dedup).
    pub raw_bytes: u64,
}

/// One connection to a [`super::server::NetServer`].
pub struct Client {
    stream: TcpStream,
    reader: FrameReader<BufReader<TcpStream>>,
    writer: FrameWriter<BufWriter<TcpStream>>,
    read_timeout_ms: u64,
    /// Optional flight recorder: short control RPCs emit `Layer::Client`
    /// spans here (the router attaches its own recorder per backend leg).
    rec: Option<Arc<Recorder>>,
    /// Round-trip latency of short control ops only — long-poll `wait`,
    /// chunked pushes and drains would swamp the distribution.
    rtt: HistogramStats,
}

impl Client {
    /// Connect and exchange preambles. `net.addr` is ignored — the
    /// explicit `addr` wins — but the frame cap and timeouts apply; the
    /// write timeout doubles as the dial deadline.
    pub fn connect(addr: &str, net: &NetConfig) -> Result<Client> {
        let stream = connect_stream(addr, net.write_timeout_ms.max(1))?;
        let _ = stream.set_nodelay(true);
        stream
            .set_write_timeout(Some(Duration::from_millis(net.write_timeout_ms.max(1))))
            .map_err(|e| Error::io("set_write_timeout", e))?;
        let read_half = stream.try_clone().map_err(|e| Error::io("clone stream", e))?;
        let mut c = Client {
            reader: FrameReader::new(BufReader::new(read_half), net.max_frame_bytes),
            writer: FrameWriter::new(BufWriter::new(stream.try_clone().map_err(
                |e| Error::io("clone stream", e),
            )?)),
            stream,
            read_timeout_ms: net.read_timeout_ms,
            rec: None,
            rtt: HistogramStats::new(),
        };
        c.set_read_timeout(c.read_timeout_ms)?;
        c.writer.write_preamble()?;
        c.reader.read_preamble()?;
        Ok(c)
    }

    fn set_read_timeout(&mut self, ms: u64) -> Result<()> {
        self.stream
            .set_read_timeout(Some(Duration::from_millis(ms.max(1))))
            .map_err(|e| Error::io("set_read_timeout", e))
    }

    /// Attach a flight recorder; subsequent short RPCs emit client spans.
    pub fn set_recorder(&mut self, rec: Arc<Recorder>) {
        self.rec = Some(rec);
    }

    /// Round-trip latency histogram of short control ops on this
    /// connection (`ping`/`submit`/`status`/`cancel`/`list`/`metrics`).
    pub fn rtt(&self) -> &HistogramStats {
        &self.rtt
    }

    /// Drain the RTT histogram, leaving it empty — the router folds each
    /// backend leg's histogram into its `net_rtt_secs` metric this way.
    pub fn take_rtt(&mut self) -> HistogramStats {
        std::mem::replace(&mut self.rtt, HistogramStats::new())
    }

    /// [`rpc`](Self::rpc) with round-trip accounting: successful calls
    /// feed the RTT histogram and, when a recorder is attached, emit a
    /// backdated `Layer::Client` span; failures emit an `rpc_error`
    /// instant instead so dead peers stay visible in the timeline.
    fn rpc_timed(
        &mut self,
        msg: &Json,
        name: &'static str,
        job: JobId,
        trace: u64,
    ) -> Result<Json> {
        let t0 = Instant::now();
        let out = self.rpc(msg);
        let dt = t0.elapsed();
        match (&out, &self.rec) {
            (Ok(_), Some(rec)) => {
                rec.span(Layer::Client, name, job, trace, dt.as_nanos() as u64, 0)
            }
            (Err(_), Some(rec)) => rec.instant(Layer::Client, "rpc_error", job, trace, 0),
            _ => {}
        }
        if out.is_ok() {
            self.rtt.record(dt.as_secs_f64());
        }
        out
    }

    /// Send `msg`, read one control reply. A `busy` reply becomes
    /// [`Error::Busy`]; any `ok:false` reply becomes an error.
    fn rpc(&mut self, msg: &Json) -> Result<Json> {
        self.writer.write_ctrl(msg)?;
        self.read_ctrl()
    }

    fn read_ctrl(&mut self) -> Result<Json> {
        match self.reader.read_frame()? {
            Frame::Ctrl(j) => Self::check(j),
            Frame::Payload(_) | Frame::Chunk(_) | Frame::Tp(_) => Err(Error::format(
                "net wire: unexpected binary frame (expected control reply)",
            )),
        }
    }

    /// Send `msg` and return the raw control reply without interpreting
    /// `ok`/`type`/`busy` — the router's relay paths forward backend
    /// verdicts verbatim. `Err` means transport/framing only.
    pub(crate) fn rpc_raw(&mut self, msg: &Json) -> Result<Json> {
        self.writer.write_ctrl(msg)?;
        match self.reader.read_frame()? {
            Frame::Ctrl(j) => Ok(j),
            Frame::Payload(_) | Frame::Chunk(_) | Frame::Tp(_) => Err(Error::format(
                "net wire: unexpected binary frame (expected control reply)",
            )),
        }
    }

    /// [`rpc_raw`](Self::rpc_raw) under a widened read deadline, restored
    /// afterwards — for replies that legitimately take longer than one
    /// RPC (a backend finalizing a push).
    pub(crate) fn rpc_raw_deadline(&mut self, msg: &Json, read_ms: u64) -> Result<Json> {
        self.set_read_timeout(read_ms.max(1))?;
        let out = self.rpc_raw(msg);
        self.set_read_timeout(self.read_timeout_ms)?;
        out
    }

    /// Forward one already-packed push chunk (router relay path).
    pub(crate) fn forward_chunk(&mut self, packed: &[u8]) -> Result<()> {
        self.writer.write_chunk(packed)
    }

    fn check(j: Json) -> Result<Json> {
        let ok = j.get("ok").and_then(|v| v.as_bool()).unwrap_or(false);
        if ok {
            return Ok(j);
        }
        let err = j
            .get("error")
            .and_then(|v| v.as_str())
            .unwrap_or("unspecified server error")
            .to_string();
        if j.get("type").and_then(|v| v.as_str()) == Some("busy") {
            Err(Error::Busy(err))
        } else {
            Err(Error::other(format!("server: {err}")))
        }
    }

    fn expect(j: &Json, kind: &str) -> Result<()> {
        match j.get("type").and_then(|v| v.as_str()) {
            Some(t) if t == kind => Ok(()),
            t => Err(Error::format(format!(
                "net wire: expected '{kind}' reply, got {t:?}"
            ))),
        }
    }

    /// Round-trip liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        let msg = Json::obj(vec![("op", Json::Str("ping".into()))]);
        let r = self.rpc_timed(&msg, "ping", 0, 0)?;
        Self::expect(&r, "pong")
    }

    /// Submit a job; returns the server-side job id, or [`Error::Busy`]
    /// when admission control rejected it (back off and retry).
    pub fn submit(&mut self, spec: &JobSpec) -> Result<JobId> {
        let msg = Json::obj(vec![
            ("op", Json::Str("submit".into())),
            ("job", spec.to_json()),
        ]);
        let r = self.rpc_timed(&msg, "submit", 0, spec.trace.unwrap_or(0))?;
        Self::expect(&r, "submitted")?;
        r.get("id")
            .and_then(|v| v.as_f64())
            .filter(|v| *v >= 0.0 && v.fract() == 0.0)
            .map(|v| v as JobId)
            .ok_or_else(|| Error::format("net wire: submitted reply without id"))
    }

    /// [`submit`](Self::submit) that guarantees the job travels with a
    /// trace id: the spec's own id is kept when set, otherwise a fresh
    /// one is generated. Returns `(job id, trace id)` so the caller can
    /// later stitch the full cross-host timeline with the `trace` op.
    pub fn submit_traced(&mut self, spec: &JobSpec) -> Result<(JobId, u64)> {
        let mut spec = spec.clone();
        let trace = spec
            .trace
            .filter(|t| *t != 0)
            .unwrap_or_else(crate::trace::gen_trace_id);
        spec.trace = Some(trace);
        let id = self.submit(&spec)?;
        Ok((id, trace))
    }

    /// Current status snapshot of `id` (the `JobView` JSON).
    pub fn status(&mut self, id: JobId) -> Result<Json> {
        let msg = Json::obj(vec![
            ("op", Json::Str("status".into())),
            ("id", Json::Num(id as f64)),
        ]);
        let r = self.rpc_timed(&msg, "status", id, 0)?;
        Self::expect(&r, "status")?;
        r.get("job")
            .cloned()
            .ok_or_else(|| Error::format("net wire: status reply without job"))
    }

    /// Fetch the server's recorded trace events. Either filter may be 0:
    /// a job id selects that job's events, a trace id additionally pulls
    /// in spans recorded before admission assigned the job id. The reply
    /// is the full `trace` object (`job`/`trace`/`events`/`dropped`) that
    /// `trace::render_human` and `trace::chrome_trace` consume.
    pub fn trace_events(&mut self, id: JobId, trace: u64) -> Result<Json> {
        let mut fields = vec![("op", Json::Str("trace".into()))];
        if id != 0 {
            fields.push(("id", Json::Num(id as f64)));
        }
        if trace != 0 {
            fields.push(("trace", Json::Str(format!("{trace:016x}"))));
        }
        let r = self.rpc(&Json::obj(fields))?;
        Self::expect(&r, "trace")?;
        Ok(r)
    }

    /// Block (server side) until `id` is terminal or `timeout` passes.
    /// `Ok(Some(result))` streams the result — including the binary
    /// sample-block payload when present — `Ok(None)` means the job was
    /// still running when the timeout hit. Timeouts beyond the server's
    /// 600 s per-request cap are honored by re-issuing the wait until
    /// the full deadline passes.
    ///
    /// A typed `busy` reply (a saturated router, or a connection-pool
    /// rejection in front of the service) is backpressure, not failure:
    /// the wait is retried with capped exponential backoff + jitter —
    /// mirroring the file transport's `wait_result_poll` — and only
    /// surfaces as [`Error::Busy`] once the deadline passes.
    pub fn wait(&mut self, id: JobId, timeout: Duration) -> Result<Option<JobResult>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut backoff = Backoff::new(1, 250, 16, id ^ self.read_timeout_ms);
        let mut last_busy: Option<Error> = None;
        loop {
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            match self.wait_once(id, remaining.min(Duration::from_secs(600))) {
                Ok(Some(res)) => return Ok(Some(res)),
                Ok(None) => {
                    if std::time::Instant::now() >= deadline {
                        return Ok(None);
                    }
                    // Server-side 600 s per-request cap; re-issue the rest.
                }
                Err(e) if e.is_busy() => {
                    if !backoff.sleep_before(deadline) {
                        return Err(e);
                    }
                    last_busy = Some(e);
                }
                Err(e) => {
                    // A failure right after a busy reply is usually the
                    // rejecting endpoint closing its lame-duck socket:
                    // surface the typed (retryable) Busy rather than the
                    // secondary transport error.
                    return Err(last_busy.unwrap_or(e));
                }
            }
        }
    }

    fn wait_once(&mut self, id: JobId, timeout: Duration) -> Result<Option<JobResult>> {
        let timeout_ms = timeout.as_millis().min(600_000) as u64;
        // The server blocks for up to timeout_ms before replying; widen
        // the socket timeout so a quiet-but-working wait is not an error.
        self.set_read_timeout(timeout_ms + self.read_timeout_ms.max(1000))?;
        let outcome: Result<Option<JobResult>> = (|| {
            let r = self.rpc(&Json::obj(vec![
                ("op", Json::Str("wait".into())),
                ("id", Json::Num(id as f64)),
                ("timeout_ms", Json::Num(timeout_ms as f64)),
            ]))?;
            match r.get("type").and_then(|v| v.as_str()) {
                Some("status") => Ok(None),
                Some("result") => {
                    let result = r
                        .get("result")
                        .cloned()
                        .ok_or_else(|| Error::format("net wire: result reply without result"))?;
                    let sink = if r.get("payload").and_then(|v| v.as_bool()) == Some(true) {
                        match self.reader.read_frame()? {
                            Frame::Payload(p) => Some(frame::unpack_sink(&p)?),
                            Frame::Ctrl(_) | Frame::Chunk(_) | Frame::Tp(_) => {
                                return Err(Error::format(
                                    "net wire: expected payload frame after result",
                                ));
                            }
                        }
                    } else {
                        None
                    };
                    Ok(Some(JobResult { result, sink }))
                }
                t => Err(Error::format(format!(
                    "net wire: unexpected wait reply type {t:?}"
                ))),
            }
        })();
        self.set_read_timeout(self.read_timeout_ms)?;
        outcome
    }

    /// Cancel a live job (terminal jobs are left as they ended).
    pub fn cancel(&mut self, id: JobId) -> Result<()> {
        let msg = Json::obj(vec![
            ("op", Json::Str("cancel".into())),
            ("id", Json::Num(id as f64)),
        ]);
        let r = self.rpc_timed(&msg, "cancel", id, 0)?;
        Self::expect(&r, "cancelled")
    }

    /// All jobs the server retains, sorted by (submit time, id).
    pub fn list(&mut self) -> Result<Json> {
        let msg = Json::obj(vec![("op", Json::Str("list".into()))]);
        let r = self.rpc_timed(&msg, "list", 0, 0)?;
        Self::expect(&r, "jobs")?;
        r.get("jobs")
            .cloned()
            .ok_or_else(|| Error::format("net wire: jobs reply without jobs"))
    }

    /// Service + net metrics snapshot.
    pub fn metrics(&mut self) -> Result<Json> {
        let msg = Json::obj(vec![("op", Json::Str("metrics".into()))]);
        let r = self.rpc_timed(&msg, "metrics", 0, 0)?;
        Self::expect(&r, "metrics")?;
        r.get("metrics")
            .cloned()
            .ok_or_else(|| Error::format("net wire: metrics reply without metrics"))
    }

    /// Ring history for dashboards (`fastmps top`): the whole
    /// `telemetry` reply — `interval_ms`, `samples` (oldest first),
    /// and, from a router, per-backend `backends` entries with their
    /// own sample rings.
    pub fn telemetry(&mut self) -> Result<Json> {
        let msg = Json::obj(vec![("op", Json::Str("telemetry".into()))]);
        let r = self.rpc_timed(&msg, "telemetry", 0, 0)?;
        Self::expect(&r, "telemetry")?;
        Ok(r)
    }

    /// Upload the `GammaStore` at `dir` (chunked, content-addressed; see
    /// `docs/PROTOCOL.md` § Chunked store push). Returns the content key
    /// to submit jobs by ([`JobSpec::by_key`]); `dedup == true` means the
    /// receiver already had the store and nothing was transferred.
    ///
    /// The upload is pipelined: a worker thread reads and LZ-compresses
    /// chunk *k+1* while the socket write of chunk *k* is in flight
    /// (bounded channel, so at most two chunks are in memory).
    ///
    /// A failed push leaves this connection out of sync with the peer —
    /// drop it and reconnect before reusing the client. A typed
    /// [`Error::Busy`] (e.g. a router that lost its backend mid-stream)
    /// is retryable on a fresh connection.
    pub fn push_store(&mut self, dir: &Path, chunk_bytes: usize) -> Result<PushReport> {
        use crate::io::{manifest_hash_at, StoreStreamSource};
        use crate::util::Fnv1a;

        let chunk_bytes = chunk_bytes.clamp(1024, 16 << 20);
        let key = manifest_hash_at(dir)?;
        // A Γ shard announces its identity up front so a routing tier can
        // record the shard map while relaying (docs/TENSOR_PARALLEL.md
        // § Group lifecycle); for whole stores the field is omitted and the
        // wire form is byte-identical to pre-TP builds.
        let shard = crate::io::GammaStore::open(dir)?.shard;
        let mut src = StoreStreamSource::open(dir)?;
        let total = src.total_len();
        let chunks = total.div_ceil(chunk_bytes as u64).max(1);
        let mut begin = vec![
            ("op", Json::Str("push_begin".into())),
            ("key", Json::Str(format!("{key:016x}"))),
            ("total_bytes", Json::Num(total as f64)),
            ("chunks", Json::Num(chunks as f64)),
        ];
        if let Some(s) = &shard {
            begin.push((
                "shard",
                Json::obj(vec![
                    ("index", Json::Num(s.index as f64)),
                    ("of", Json::Num(s.of as f64)),
                    ("base", Json::Str(format!("{:016x}", s.base))),
                ]),
            ));
        }
        let r = self.rpc(&Json::obj(begin))?;
        Self::expect(&r, "push_ready")?;
        if r.get("dedup").and_then(|v| v.as_bool()) == Some(true) {
            return Ok(PushReport {
                key,
                dedup: true,
                chunks: 0,
                raw_bytes: 0,
            });
        }

        let (tx, rx) = std::sync::mpsc::sync_channel::<Vec<u8>>(2);
        let worker = std::thread::spawn(move || -> Result<u64> {
            let mut fnv = Fnv1a::new();
            let mut buf = vec![0u8; chunk_bytes];
            let mut index = 0u64;
            loop {
                let n = src.read_chunk(&mut buf)?;
                if n == 0 {
                    break;
                }
                fnv.update(&buf[..n]);
                let packed = frame::encode_chunk(index, fnv.digest(), &buf[..n]);
                index += 1;
                if tx.send(packed).is_err() {
                    break; // writer side bailed; it carries the error
                }
            }
            Ok(fnv.digest())
        });
        let mut write_err: Option<Error> = None;
        loop {
            let packed = match rx.recv() {
                Ok(p) => p,
                Err(_) => break, // worker done (or died; join reports it)
            };
            if let Err(e) = self.writer.write_chunk(&packed) {
                write_err = Some(e);
                break;
            }
        }
        drop(rx); // unblock a worker still waiting on channel capacity
        let checksum = worker
            .join()
            .map_err(|_| Error::other("push worker panicked"))??;
        if let Some(e) = write_err {
            return Err(e);
        }

        // Finalization (verify + rename + open) can outlast the per-RPC
        // read deadline; widen it for the closing exchange (same floor
        // the router's relay applies on its backend leg).
        self.set_read_timeout(NetConfig::push_end_timeout_ms(self.read_timeout_ms))?;
        let end = self.rpc(&Json::obj(vec![
            ("op", Json::Str("push_end".into())),
            ("checksum", Json::Str(format!("{checksum:016x}"))),
        ]));
        self.set_read_timeout(self.read_timeout_ms)?;
        let end = end?;
        Self::expect(&end, "pushed")?;
        Ok(PushReport {
            key,
            dedup: end.get("dedup").and_then(|v| v.as_bool()) == Some(true),
            chunks,
            raw_bytes: total,
        })
    }

    /// Ask the server to drain in-flight jobs and stop; returns its final
    /// metrics. The reply only arrives once the drain completes, so this
    /// can block for as long as the queued work takes.
    pub fn shutdown_server(&mut self, drain_timeout: Duration) -> Result<Json> {
        let ms = drain_timeout.as_millis().min(u128::from(u64::MAX)) as u64;
        self.set_read_timeout(ms.max(1000))?;
        let r = self.rpc(&Json::obj(vec![("op", Json::Str("shutdown".into()))]))?;
        Self::expect(&r, "shutdown")?;
        r.get("metrics")
            .cloned()
            .ok_or_else(|| Error::format("net wire: shutdown reply without metrics"))
    }
}

/// Resolve and dial with a connect deadline, so a blackholed peer (dead
/// IP, dropped packets) cannot stall callers for the OS default of
/// minutes — the router's health prober depends on failing fast here.
fn connect_stream(addr: &str, timeout_ms: u64) -> Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let timeout = Duration::from_millis(timeout_ms);
    let addrs = addr
        .to_socket_addrs()
        .map_err(|e| Error::io(format!("resolve {addr}"), e))?;
    let mut last: Option<std::io::Error> = None;
    for a in addrs {
        match TcpStream::connect_timeout(&a, timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
    }
    Err(Error::io(
        format!("connect {addr}"),
        last.unwrap_or_else(|| std::io::Error::other("address resolved to nothing")),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufReader as IoBufReader, BufWriter as IoBufWriter};
    use std::net::TcpListener;
    use std::time::Instant;

    /// A minimal scripted FMPN endpoint: replies `busy` to the first
    /// `busy_replies` wait ops, then a terminal `result`. Returns the
    /// number of wait ops it served.
    fn scripted_server(busy_replies: usize) -> (String, std::thread::JoinHandle<usize>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut w = FrameWriter::new(IoBufWriter::new(stream.try_clone().unwrap()));
            let mut r = FrameReader::new(IoBufReader::new(stream), 1 << 20);
            w.write_preamble().unwrap();
            r.read_preamble().unwrap();
            let mut waits = 0usize;
            loop {
                let msg = match r.read_frame() {
                    Ok(Frame::Ctrl(msg)) => msg,
                    _ => return waits, // client hung up
                };
                assert_eq!(msg.get("op").and_then(|v| v.as_str()), Some("wait"));
                waits += 1;
                if waits <= busy_replies {
                    w.write_ctrl(&Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("type", Json::Str("busy".into())),
                        ("error", Json::Str("queue full".into())),
                    ]))
                    .unwrap();
                } else {
                    w.write_ctrl(&Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("type", Json::Str("result".into())),
                        (
                            "result",
                            Json::obj(vec![
                                ("id", Json::Num(7.0)),
                                ("status", Json::Str("done".into())),
                            ]),
                        ),
                        ("payload", Json::Bool(false)),
                    ]))
                    .unwrap();
                    return waits;
                }
            }
        });
        (addr, handle)
    }

    #[test]
    fn wait_backs_off_and_retries_through_busy() {
        let (addr, server) = scripted_server(2);
        let net = NetConfig {
            addr: addr.clone(),
            ..NetConfig::default()
        };
        let mut c = Client::connect(&addr, &net).unwrap();
        let t0 = Instant::now();
        let res = c
            .wait(7, Duration::from_secs(30))
            .unwrap()
            .expect("terminal result after busy replies");
        // Two busy replies ⇒ two backoff sleeps (1 ms + 2 ms minimum).
        assert!(t0.elapsed() >= Duration::from_millis(3), "{:?}", t0.elapsed());
        assert_eq!(
            res.result.get("status").and_then(|v| v.as_str()),
            Some("done")
        );
        assert!(res.sink.is_none());
        assert_eq!(server.join().unwrap(), 3, "busy, busy, result");
    }

    #[test]
    fn wait_surfaces_busy_once_the_deadline_passes() {
        let (addr, server) = scripted_server(usize::MAX);
        let net = NetConfig {
            addr: addr.clone(),
            ..NetConfig::default()
        };
        let mut c = Client::connect(&addr, &net).unwrap();
        let err = c
            .wait(7, Duration::from_millis(60))
            .expect_err("permanently busy must surface as Busy");
        assert!(err.is_busy(), "typed busy, got: {err}");
        drop(c); // server loop exits on EOF
        assert!(server.join().unwrap() >= 1);
    }
}
