//! `fastmps top` — terminal dashboard over ring history.
//!
//! The CLI fetches the `telemetry` op reply (ring history, plus
//! per-backend rings when pointed at a router), parses it into a
//! [`TopView`], and redraws [`render`]'s frame on its own interval.
//! Rendering is a pure function of the view — no I/O, no clock — so
//! the frame is unit-testable offline and `--once` can print a single
//! frame for scripts.

use crate::util::json::Json;

use super::{rates, TsRates, TsSample};

/// Sparkline glyphs, lowest to highest.
const SPARKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Map a series onto sparkline glyphs, scaled to the series max.
/// All-zero (or empty) series render flat.
pub fn sparkline(values: &[f64]) -> String {
    let max = values.iter().cloned().fold(0.0f64, f64::max);
    values
        .iter()
        .map(|&v| {
            if !(max > 0.0) || !(v > 0.0) {
                SPARKS[0]
            } else {
                let idx = (v / max * (SPARKS.len() - 1) as f64).round() as usize;
                SPARKS[idx.min(SPARKS.len() - 1)]
            }
        })
        .collect()
}

/// One backend row when watching a router.
pub struct TopBackend {
    pub index: usize,
    pub addr: String,
    pub state: String,
    pub samples: Vec<TsSample>,
}

/// Everything one frame needs, parsed from a `telemetry` reply.
pub struct TopView {
    /// Address the dashboard is connected to (display only).
    pub addr: String,
    /// Server-side sampling interval.
    pub interval_ms: u64,
    /// The watched process's own ring, oldest first.
    pub samples: Vec<TsSample>,
    /// Per-backend rings (non-empty only against a router).
    pub backends: Vec<TopBackend>,
}

fn parse_samples(j: Option<&Json>) -> Vec<TsSample> {
    j.and_then(|v| v.as_arr())
        .map(|arr| arr.iter().map(TsSample::from_json).collect())
        .unwrap_or_default()
}

impl TopView {
    /// Parse the `telemetry` op reply.
    pub fn parse(addr: &str, reply: &Json) -> TopView {
        let backends = reply
            .get("backends")
            .and_then(|b| b.as_arr())
            .map(|arr| {
                arr.iter()
                    .enumerate()
                    .map(|(i, b)| TopBackend {
                        index: b.get("backend").and_then(|v| v.as_usize()).unwrap_or(i),
                        addr: b.get("addr").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
                        state: b.get("state").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
                        samples: parse_samples(b.get("samples")),
                    })
                    .collect()
            })
            .unwrap_or_default();
        TopView {
            addr: addr.to_string(),
            interval_ms: reply.get("interval_ms").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            samples: parse_samples(reply.get("samples")),
            backends,
        }
    }
}

/// Width of each sparkline: the rightmost hour at 1 s samples still
/// fits a normal terminal when prefixed with the value column.
const SPARK_WIDTH: usize = 40;

fn tail(values: Vec<f64>) -> Vec<f64> {
    let skip = values.len().saturating_sub(SPARK_WIDTH);
    values.into_iter().skip(skip).collect()
}

/// Per-adjacent-pair rate series over the ring (len - 1 points).
fn rate_series(samples: &[TsSample], pick: impl Fn(&TsRates) -> f64) -> Vec<f64> {
    samples.windows(2).map(|w| pick(&rates(&w[0], &w[1]))).collect()
}

fn gauge_series(samples: &[TsSample], pick: impl Fn(&TsSample) -> f64) -> Vec<f64> {
    samples.iter().map(pick).collect()
}

fn fmt_bytes_rate(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2} GB/s", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2} MB/s", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1} kB/s", v / 1e3)
    } else {
        format!("{v:.0} B/s")
    }
}

fn fmt_secs(v: Option<f64>) -> String {
    match v {
        None => "-".to_string(),
        Some(s) if s >= 1.0 => format!("{s:.2} s"),
        Some(s) if s >= 1e-3 => format!("{:.2} ms", s * 1e3),
        Some(s) => format!("{:.1} µs", s * 1e6),
    }
}

fn line(out: &mut String, label: &str, value: String, spark: &str) {
    out.push_str(&format!("  {label:<18} {value:>14}  {spark}\n"));
}

/// Render one dashboard frame (no ANSI control codes — the CLI adds
/// clear-screen between frames).
pub fn render(view: &TopView) -> String {
    let mut out = String::new();
    let s = &view.samples;
    out.push_str(&format!(
        "fastmps top — {} — {} sample(s) @ {} ms\n\n",
        view.addr,
        s.len(),
        view.interval_ms
    ));
    if s.is_empty() {
        out.push_str("  (no telemetry samples yet)\n");
        return out;
    }
    let last = s[s.len() - 1];
    let cur_rates = if s.len() >= 2 { rates(&s[s.len() - 2], &last) } else { TsRates::default() };

    let depth = tail(gauge_series(s, |x| x.queue_depth as f64));
    line(&mut out, "queue depth", format!("{}", last.queue_depth), &sparkline(&depth));
    let inflight = tail(gauge_series(s, |x| x.inflight_batches as f64));
    line(&mut out, "inflight batches", format!("{}", last.inflight_batches), &sparkline(&inflight));

    let jobs = tail(rate_series(s, |r| r.jobs_per_sec));
    line(&mut out, "jobs/s", format!("{:.1}", cur_rates.jobs_per_sec), &sparkline(&jobs));
    let steps = tail(rate_series(s, |r| r.steps_per_sec));
    line(&mut out, "steps/s", format!("{:.0}", cur_rates.steps_per_sec), &sparkline(&steps));
    let bin = tail(rate_series(s, |r| r.bytes_in_per_sec));
    line(&mut out, "net in", fmt_bytes_rate(cur_rates.bytes_in_per_sec), &sparkline(&bin));
    let bout = tail(rate_series(s, |r| r.bytes_out_per_sec));
    line(&mut out, "net out", fmt_bytes_rate(cur_rates.bytes_out_per_sec), &sparkline(&bout));

    let hit = match last.cache_hit_rate {
        Some(r) => format!("{:.1}%", r * 100.0),
        None => "-".to_string(),
    };
    let hits = tail(gauge_series(s, |x| x.cache_hit_rate.unwrap_or(0.0)));
    line(&mut out, "cache hit", hit, &sparkline(&hits));

    let qw99 = tail(gauge_series(s, |x| x.queue_wait_p99.unwrap_or(0.0)));
    line(
        &mut out,
        "queue wait p50/p99",
        format!("{} / {}", fmt_secs(last.queue_wait_p50), fmt_secs(last.queue_wait_p99)),
        &sparkline(&qw99),
    );
    if last.rtt_p50.is_some() || last.rtt_p99.is_some() {
        let rtt99 = tail(gauge_series(s, |x| x.rtt_p99.unwrap_or(0.0)));
        line(
            &mut out,
            "rtt p50/p99",
            format!("{} / {}", fmt_secs(last.rtt_p50), fmt_secs(last.rtt_p99)),
            &sparkline(&rtt99),
        );
    }

    if !view.backends.is_empty() {
        out.push_str("\nbackends:\n");
        for b in &view.backends {
            let (depth, jps, p99) = match b.samples.last() {
                Some(last) => {
                    let jps = if b.samples.len() >= 2 {
                        rates(&b.samples[b.samples.len() - 2], last).jobs_per_sec
                    } else {
                        0.0
                    };
                    (format!("{}", last.queue_depth), format!("{jps:.1}"), fmt_secs(last.queue_wait_p99))
                }
                None => ("-".into(), "-".into(), "-".into()),
            };
            let jobs = tail(rate_series(&b.samples, |r| r.jobs_per_sec));
            out.push_str(&format!(
                "  [{}] {:<21} {:<8} q={:<4} jobs/s={:<6} p99 wait={:<9} {}\n",
                b.index,
                b.addr,
                b.state,
                depth,
                jps,
                p99,
                sparkline(&jobs),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(t: u64, jobs: u64, depth: u64) -> TsSample {
        TsSample {
            unix_ms: t,
            queue_depth: depth,
            inflight_batches: 2,
            cache_hit_rate: Some(0.75),
            jobs_submitted: jobs + 1,
            jobs_completed: jobs,
            jobs_failed: 0,
            samples_done: jobs * 10,
            steps: jobs * 100,
            net_bytes_in: jobs * 1000,
            net_bytes_out: jobs * 2000,
            queue_wait_p50: Some(0.002),
            queue_wait_p99: Some(0.05),
            rtt_p50: None,
            rtt_p99: None,
        }
    }

    #[test]
    fn sparkline_scales_to_max() {
        assert_eq!(sparkline(&[]), "");
        assert_eq!(sparkline(&[0.0, 0.0]), "▁▁");
        let line = sparkline(&[1.0, 4.0, 8.0]);
        assert_eq!(line.chars().count(), 3);
        assert_eq!(line.chars().last(), Some('█'));
        assert!(line.chars().next().unwrap() <= line.chars().last().unwrap());
    }

    #[test]
    fn frame_renders_required_fields() {
        let view = TopView {
            addr: "127.0.0.1:7733".into(),
            interval_ms: 1000,
            samples: (0..10).map(|i| s(i * 1000, i * 3, 5 - (i % 3))).collect(),
            backends: vec![],
        };
        let frame = render(&view);
        // The acceptance trio: queue depth, jobs/s, p99 queue wait.
        assert!(frame.contains("queue depth"));
        assert!(frame.contains("jobs/s"));
        assert!(frame.contains("p99"));
        assert!(frame.contains("3.0"), "3 jobs per 1000 ms should show as 3.0 jobs/s: {frame}");
        assert!(frame.contains("50.00 ms"), "p99 queue wait missing: {frame}");
        assert!(frame.contains('█'), "sparklines should render: {frame}");
        // No RTT row for a plain server (rtt is None throughout).
        assert!(!frame.contains("rtt p50"));
    }

    #[test]
    fn router_view_renders_backend_rows() {
        let reply = Json::obj(vec![
            ("type", Json::Str("telemetry".into())),
            ("interval_ms", Json::Num(500.0)),
            ("samples", Json::Arr(vec![s(0, 0, 1).to_json(), s(500, 5, 1).to_json()])),
            (
                "backends",
                Json::Arr(vec![Json::obj(vec![
                    ("backend", Json::Num(0.0)),
                    ("addr", Json::Str("127.0.0.1:9001".into())),
                    ("state", Json::Str("alive".into())),
                    ("samples", Json::Arr(vec![s(0, 0, 2).to_json(), s(500, 2, 2).to_json()])),
                ])]),
            ),
        ]);
        let view = TopView::parse("127.0.0.1:7070", &reply);
        assert_eq!(view.interval_ms, 500);
        assert_eq!(view.samples.len(), 2);
        assert_eq!(view.backends.len(), 1);
        assert_eq!(view.backends[0].state, "alive");
        let frame = render(&view);
        assert!(frame.contains("backends:"));
        assert!(frame.contains("[0] 127.0.0.1:9001"));
        assert!(frame.contains("alive"));
        assert!(frame.contains("q=2"));
    }

    #[test]
    fn empty_view_renders_placeholder() {
        let view = TopView { addr: "x".into(), interval_ms: 1000, samples: vec![], backends: vec![] };
        assert!(render(&view).contains("no telemetry samples yet"));
    }
}
