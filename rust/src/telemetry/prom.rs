//! Prometheus text-format exposition (version 0.0.4) over the metrics
//! registry, zero dependencies.
//!
//! The renderer is JSON-driven: it consumes the `fastmps metrics
//! --json` document rather than a live [`Metrics`] — so a router can
//! render metrics it *scraped* from a backend over FMPN exactly the
//! way a server renders its own, just with a `backend="N"` label
//! prepended. Naming conventions (documented in
//! `docs/OBSERVABILITY.md`):
//!
//! - everything is prefixed `fastmps_`;
//! - counters keep their registry key and gain `_total` (unless the
//!   key already ends in `_total`);
//! - the documented peak gauges (`metrics::keys::PEAK_GAUGES`) and
//!   derived instantaneous values (`queue_depth`, `cache_hit_rate`,
//!   …) are `gauge`;
//! - phase timers fold into one counter family,
//!   `fastmps_phase_seconds_total{phase="..."}`;
//! - a `<stem>_secs` histogram becomes `fastmps_<stem>_seconds` with
//!   cumulative `le` buckets: log₂ bucket *i* (floor `2^(i-30)` s)
//!   contributes its upper edge `2^(i-29)` as `le`, zero-count buckets
//!   are omitted, and the terminal `le="+Inf"` equals `_count`.

use std::collections::BTreeMap;

use crate::metrics::{keys, HistogramStats, HIST_BUCKETS};
use crate::util::json::Json;

/// Map a log₂ histogram to cumulative Prometheus buckets:
/// `(upper_edge_secs, cumulative_count)` pairs for each *occupied*
/// bucket, ascending. The caller appends `le="+Inf"` = `count`.
pub fn cumulative_le(h: &HistogramStats) -> Vec<(f64, u64)> {
    let mut out = Vec::new();
    let mut cum = 0u64;
    for (i, &n) in h.bucket_counts().iter().enumerate() {
        if n == 0 {
            continue;
        }
        cum += n;
        out.push((HistogramStats::bucket_floor(i + 1), cum));
    }
    out
}

fn cumulative_le_sparse(buckets: &[Json]) -> Vec<(f64, u64)> {
    let mut out = Vec::new();
    let mut cum = 0u64;
    for pair in buckets {
        let p = match pair.as_arr() {
            Some(p) if p.len() == 2 => p,
            _ => continue,
        };
        let i = p[0].as_usize().unwrap_or(0).min(HIST_BUCKETS - 1);
        let n = p[1].as_f64().unwrap_or(0.0).max(0.0) as u64;
        if n == 0 {
            continue;
        }
        cum += n;
        out.push((HistogramStats::bucket_floor(i + 1), cum));
    }
    out
}

/// `fastmps_`-prefix a registry key, mapping any stray character
/// outside the Prometheus name charset to `_`.
pub fn metric_name(key: &str) -> String {
    let mut out = String::with_capacity(key.len() + 8);
    out.push_str("fastmps_");
    for c in key.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn counter_name(key: &str) -> String {
    let base = metric_name(key);
    if base.ends_with("_total") {
        base
    } else {
        base + "_total"
    }
}

fn hist_name(key: &str) -> String {
    metric_name(key.strip_suffix("_secs").unwrap_or(key)) + "_seconds"
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        return "NaN".into();
    }
    if v.is_infinite() {
        return if v > 0.0 { "+Inf".into() } else { "-Inf".into() };
    }
    if v == v.trunc() && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn label_block(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let body: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", body.join(","))
}

struct Family {
    kind: &'static str,
    help: String,
    lines: Vec<String>,
}

/// Accumulates samples grouped by metric family, then renders them in
/// deterministic (alphabetical) order with `# HELP`/`# TYPE` headers
/// emitted exactly once per family.
pub struct Exposition {
    families: BTreeMap<String, Family>,
}

impl Default for Exposition {
    fn default() -> Self {
        Self::new()
    }
}

impl Exposition {
    pub fn new() -> Exposition {
        Exposition { families: BTreeMap::new() }
    }

    fn family(&mut self, name: &str, kind: &'static str, help: &str) -> &mut Family {
        self.families.entry(name.to_string()).or_insert_with(|| Family {
            kind,
            help: help.to_string(),
            lines: Vec::new(),
        })
    }

    /// One gauge sample; `key` is the raw registry key (prefixed and
    /// sanitized here).
    pub fn gauge(&mut self, key: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        let name = metric_name(key);
        let lb = label_block(labels);
        let line = format!("{name}{lb} {}", fmt_value(v));
        self.family(&name, "gauge", help).lines.push(line);
    }

    /// One counter sample; the family name gains `_total` unless the
    /// key already carries it.
    pub fn counter(&mut self, key: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        let name = counter_name(key);
        let lb = label_block(labels);
        let line = format!("{name}{lb} {}", fmt_value(v));
        self.family(&name, "counter", help).lines.push(line);
    }

    fn hist_lines(
        &mut self,
        key: &str,
        help: &str,
        labels: &[(&str, &str)],
        le: &[(f64, u64)],
        count: u64,
        sum: f64,
    ) {
        let name = hist_name(key);
        let fam = self.family(&name, "histogram", help);
        for &(edge, cum) in le {
            let mut with_le: Vec<(&str, &str)> = labels.to_vec();
            let edge_s = fmt_value(edge);
            with_le.push(("le", edge_s.as_str()));
            fam.lines.push(format!("{name}_bucket{} {cum}", label_block(&with_le)));
        }
        let mut inf: Vec<(&str, &str)> = labels.to_vec();
        inf.push(("le", "+Inf"));
        fam.lines.push(format!("{name}_bucket{} {count}", label_block(&inf)));
        let lb = label_block(labels);
        fam.lines.push(format!("{name}_sum{lb} {}", fmt_value(sum)));
        fam.lines.push(format!("{name}_count{lb} {count}"));
    }

    /// A live histogram (used by unit tests and anything holding a
    /// `HistogramStats` directly).
    pub fn histogram(&mut self, key: &str, help: &str, labels: &[(&str, &str)], h: &HistogramStats) {
        self.hist_lines(key, help, labels, &cumulative_le(h), h.count, h.sum);
    }

    fn histogram_json(&mut self, key: &str, labels: &[(&str, &str)], h: &Json) {
        let count = h.get("count").and_then(|v| v.as_f64()).unwrap_or(0.0).max(0.0) as u64;
        let sum = h.get("sum_secs").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let le = match h.get("buckets").and_then(|b| b.as_arr()) {
            Some(pairs) => cumulative_le_sparse(pairs),
            None => Vec::new(),
        };
        let help = format!("Log2-bucketed duration histogram {key} (seconds).");
        self.hist_lines(key, &help, labels, &le, count, sum);
    }

    fn counters_obj(&mut self, counters: &Json, labels: &[(&str, &str)]) {
        if let Json::Obj(map) = counters {
            for (k, v) in map {
                let v = v.as_f64().unwrap_or(0.0);
                if keys::PEAK_GAUGES.contains(&k.as_str()) {
                    self.gauge(k, &format!("High-water mark of {k}."), labels, v);
                } else {
                    self.counter(k, &format!("Lifetime total of {k}."), labels, v);
                }
            }
        }
    }

    /// Render a full `fastmps metrics --json` document (server or
    /// router shape) into exposition samples, every one carrying
    /// `labels`. The `backends` array is *not* descended into — the
    /// router adds each scraped backend document itself, labeled.
    pub fn add_metrics_json(&mut self, doc: &Json, labels: &[(&str, &str)]) {
        if let Some(run) = doc.get("run") {
            if let Some(c) = run.get("counters") {
                self.counters_obj(c, labels);
            }
            if let Some(Json::Obj(phases)) = run.get("phases") {
                for (phase, secs) in phases {
                    let mut with_phase: Vec<(&str, &str)> = labels.to_vec();
                    with_phase.push(("phase", phase.as_str()));
                    self.counter(
                        "phase_seconds",
                        "Cumulative seconds spent per engine phase.",
                        &with_phase,
                        secs.as_f64().unwrap_or(0.0),
                    );
                }
            }
            if let Some(f) = run.get("achieved_flops").and_then(|v| v.as_f64()) {
                self.gauge("achieved_flops", "Achieved FLOP rate over the run.", labels, f);
            }
            if let Some(Json::Obj(hists)) = run.get("hists") {
                for (k, h) in hists {
                    self.histogram_json(k, labels, h);
                }
            }
        }
        if let Some(c) = doc.get("net").and_then(|n| n.get("counters")) {
            self.counters_obj(c, labels);
        }
        for (key, help) in [
            ("queue_depth", "Live (non-terminal) jobs in the queue."),
            ("inflight_batches", "Batches formed and not yet retired."),
            ("cache_hit_rate", "Lifetime store-cache hit rate."),
            ("batch_occupancy", "Filled fraction of dispatched batch rows."),
            ("prep_resident_bytes", "Bytes of precision-prepared chains resident."),
            ("jobs_in_flight", "Jobs routed and not yet terminal."),
        ] {
            if let Some(v) = doc.get(key).and_then(|v| v.as_f64()) {
                self.gauge(key, help, labels, v);
            }
        }
        if let Some(v) = doc.get("jobs_routed").and_then(|v| v.as_f64()) {
            self.counter("jobs_routed", "Lifetime jobs routed to any backend.", labels, v);
        }
        if let Some(lat) = doc.get("latency") {
            for (field, key) in [
                ("p50_secs", "latency_p50_seconds"),
                ("p99_secs", "latency_p99_seconds"),
                ("max_secs", "latency_max_seconds"),
            ] {
                if let Some(v) = lat.get(field).and_then(|v| v.as_f64()) {
                    self.gauge(key, "Job latency over the recent exact window.", labels, v);
                }
            }
        }
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, fam) in &self.families {
            out.push_str(&format!("# HELP {name} {}\n", fam.help));
            out.push_str(&format!("# TYPE {name} {}\n", fam.kind));
            for line in &fam.lines {
                out.push_str(line);
                out.push('\n');
            }
        }
        out
    }
}

/// Render one metrics document with no labels — the whole `/metrics`
/// body for a plain server.
pub fn render_document(doc: &Json) -> String {
    let mut e = Exposition::new();
    e.add_metrics_json(doc, &[]);
    e.render()
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_value(s: &str) -> Option<f64> {
    match s {
        "+Inf" => Some(f64::INFINITY),
        "-Inf" => Some(f64::NEG_INFINITY),
        "NaN" => Some(f64::NAN),
        _ => s.parse::<f64>().ok(),
    }
}

/// Split `name{labels} value` into parts; labels come back as
/// `(name, value)` pairs with escapes undone.
#[allow(clippy::type_complexity)]
fn parse_sample(line: &str) -> Result<(String, Vec<(String, String)>, f64), String> {
    let bad = |m: &str| Err(format!("{m}: {line}"));
    let (name_part, rest) = match line.find('{') {
        Some(b) => (&line[..b], &line[b..]),
        None => match line.find(' ') {
            Some(sp) => (&line[..sp], &line[sp..]),
            None => return bad("sample line without value"),
        },
    };
    if !valid_metric_name(name_part) {
        return bad("invalid metric name");
    }
    let mut labels = Vec::new();
    let value_part;
    if let Some(rest2) = rest.strip_prefix('{') {
        let close = match rest2.find('}') {
            Some(c) => c,
            None => return bad("unterminated label block"),
        };
        // Escaped quotes never occur in names we emit; a simple split
        // on '}' is safe because label values escape backslash-quote
        // but the block-terminating brace is never inside quotes in
        // this validator's inputs (we also re-check pair syntax below).
        let body = &rest2[..close];
        value_part = rest2[close + 1..].trim();
        for pair in body.split(',') {
            if pair.is_empty() {
                continue;
            }
            let eq = match pair.find('=') {
                Some(e) => e,
                None => return bad("label pair without '='"),
            };
            let (ln, lv) = (&pair[..eq], &pair[eq + 1..]);
            if !valid_label_name(ln) {
                return bad("invalid label name");
            }
            if lv.len() < 2 || !lv.starts_with('"') || !lv.ends_with('"') {
                return bad("label value not quoted");
            }
            labels.push((ln.to_string(), lv[1..lv.len() - 1].replace("\\\"", "\"")));
        }
    } else {
        value_part = rest.trim();
    }
    let v = match parse_value(value_part) {
        Some(v) => v,
        None => return bad("unparseable sample value"),
    };
    Ok((name_part.to_string(), labels, v))
}

/// Validate exposition text against the conventions the CI gate
/// (`.github/scripts/check_exposition.sh`) enforces on the committed
/// fixture: name/label charset, HELP-then-TYPE pairing declared before
/// any sample, known TYPE kinds, counters ending `_total`, and per
/// histogram series monotone cumulative `le` buckets terminated by
/// `le="+Inf"` equal to `_count`.
pub fn validate_exposition(text: &str) -> Result<(), String> {
    let mut help: BTreeMap<String, ()> = BTreeMap::new();
    let mut kind: BTreeMap<String, String> = BTreeMap::new();
    // (family, non-le labelset) -> (le, cum) in emission order.
    let mut series: BTreeMap<(String, String), Vec<(f64, f64)>> = BTreeMap::new();
    let mut counts: BTreeMap<(String, String), f64> = BTreeMap::new();
    let mut sums: BTreeMap<(String, String), bool> = BTreeMap::new();

    for (ln, line) in text.lines().enumerate() {
        let ctx = |m: String| format!("line {}: {m}", ln + 1);
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap_or("");
            if !valid_metric_name(name) {
                return Err(ctx(format!("bad HELP name '{name}'")));
            }
            if help.insert(name.to_string(), ()).is_some() {
                return Err(ctx(format!("duplicate HELP for '{name}'")));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().unwrap_or("");
            let k = it.next().unwrap_or("");
            if !valid_metric_name(name) {
                return Err(ctx(format!("bad TYPE name '{name}'")));
            }
            if !matches!(k, "counter" | "gauge" | "histogram") {
                return Err(ctx(format!("unknown TYPE kind '{k}'")));
            }
            if !help.contains_key(name) {
                return Err(ctx(format!("TYPE before HELP for '{name}'")));
            }
            if kind.insert(name.to_string(), k.to_string()).is_some() {
                return Err(ctx(format!("duplicate TYPE for '{name}'")));
            }
            continue;
        }
        if line.starts_with('#') {
            return Err(ctx("unexpected comment (only HELP/TYPE allowed)".into()));
        }
        let (name, labels, value) = parse_sample(line).map_err(&ctx)?;
        // Resolve the family: histogram series samples use suffixes.
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|s| {
                let stem = name.strip_suffix(s)?;
                (kind.get(stem).map(String::as_str) == Some("histogram")).then(|| stem.to_string())
            })
            .unwrap_or_else(|| name.clone());
        let fam_kind = match kind.get(&family) {
            Some(k) => k.as_str(),
            None => return Err(ctx(format!("sample for undeclared family '{family}'"))),
        };
        match fam_kind {
            "counter" => {
                if !family.ends_with("_total") {
                    return Err(ctx(format!("counter '{family}' must end in _total")));
                }
                if value < 0.0 {
                    return Err(ctx(format!("negative counter sample '{name}'")));
                }
            }
            "histogram" => {
                let non_le: Vec<String> = labels
                    .iter()
                    .filter(|(k, _)| k != "le")
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect();
                let skey = (family.clone(), non_le.join(","));
                if name.ends_with("_bucket") {
                    let le = labels.iter().find(|(k, _)| k == "le");
                    let le = match le {
                        Some((_, v)) => match parse_value(v) {
                            Some(le) => le,
                            None => return Err(ctx("unparseable le".into())),
                        },
                        None => return Err(ctx("_bucket without le label".into())),
                    };
                    series.entry(skey).or_default().push((le, value));
                } else if name.ends_with("_count") {
                    counts.insert(skey, value);
                } else if name.ends_with("_sum") {
                    sums.insert(skey, true);
                } else {
                    return Err(ctx(format!("bare sample for histogram '{family}'")));
                }
            }
            _ => {}
        }
    }

    for (skey, buckets) in &series {
        let label = format!("{}{{{}}}", skey.0, skey.1);
        let mut prev_le = f64::NEG_INFINITY;
        let mut prev_cum = -1.0;
        for &(le, cum) in buckets {
            if le <= prev_le {
                return Err(format!("{label}: le not strictly increasing"));
            }
            if cum < prev_cum {
                return Err(format!("{label}: cumulative bucket counts decreased"));
            }
            prev_le = le;
            prev_cum = cum;
        }
        match buckets.last() {
            Some(&(le, cum)) if le.is_infinite() => {
                match counts.get(skey) {
                    Some(&c) if c == cum => {}
                    Some(_) => return Err(format!("{label}: +Inf bucket != _count")),
                    None => return Err(format!("{label}: histogram without _count")),
                }
            }
            _ => return Err(format!("{label}: last bucket must be le=\"+Inf\"")),
        }
        if !sums.contains_key(skey) {
            return Err(format!("{label}: histogram without _sum"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;

    #[test]
    fn cumulative_le_is_monotone_and_inf_matches_count() {
        let mut h = HistogramStats::new();
        for v in [1e-9, 0.5e-3, 1e-3, 2e-3, 0.75, 1e9] {
            h.record(v);
        }
        let le = cumulative_le(&h);
        assert!(!le.is_empty());
        let mut prev_edge = f64::NEG_INFINITY;
        let mut prev_cum = 0;
        for &(edge, cum) in &le {
            assert!(edge > prev_edge, "le edges must increase");
            assert!(cum >= prev_cum, "cumulative counts must not decrease");
            prev_edge = edge;
            prev_cum = cum;
        }
        // The final occupied bucket accumulates everything == count.
        assert_eq!(le.last().unwrap().1, h.count);
        // Upper edge of bucket i is the floor of bucket i+1: a value
        // recorded at exactly 2^-10 lands strictly below edge 2^-9.
        let mut one = HistogramStats::new();
        one.record((2.0f64).powi(-10));
        let le = cumulative_le(&one);
        assert_eq!(le, vec![((2.0f64).powi(-9), 1)]);
    }

    #[test]
    fn renders_counters_gauges_phases_and_histograms() {
        let mut m = Metrics::new();
        m.add(keys::JOBS_COMPLETED, 5);
        m.add(keys::SAMPLES, 500);
        m.set_max(keys::QUEUE_PEAK, 7);
        m.add_phase("compute", 1.25);
        m.observe(keys::HIST_QUEUE_WAIT, 0.01);
        m.observe(keys::HIST_QUEUE_WAIT, 0.04);
        let doc = Json::obj(vec![("run", m.to_json())]);
        let text = render_document(&doc);
        assert!(text.contains("# TYPE fastmps_jobs_completed_total counter"));
        assert!(text.contains("fastmps_jobs_completed_total 5"));
        assert!(text.contains("# TYPE fastmps_queue_peak gauge"));
        assert!(text.contains("fastmps_queue_peak 7"));
        assert!(text.contains("fastmps_phase_seconds_total{phase=\"compute\"} 1.25"));
        assert!(text.contains("# TYPE fastmps_queue_wait_seconds histogram"));
        assert!(text.contains("fastmps_queue_wait_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("fastmps_queue_wait_seconds_count 2"));
        validate_exposition(&text).unwrap();
    }

    #[test]
    fn labels_ride_every_sample_and_escape() {
        let mut e = Exposition::new();
        e.counter("jobs_completed", "h", &[("backend", "0")], 3.0);
        e.counter("jobs_completed", "h", &[("backend", "1")], 4.0);
        e.gauge("weird", "h", &[("addr", "a\"b\\c")], 1.0);
        let text = e.render();
        assert!(text.contains("fastmps_jobs_completed_total{backend=\"0\"} 3"));
        assert!(text.contains("fastmps_jobs_completed_total{backend=\"1\"} 4"));
        assert!(text.contains("{addr=\"a\\\"b\\\\c\"}"));
        // One header pair even with two labeled samples.
        assert_eq!(text.matches("# TYPE fastmps_jobs_completed_total").count(), 1);
    }

    #[test]
    fn scraped_backend_document_renders_with_labels() {
        let doc = Json::parse(
            r#"{
              "run": {"phases": {}, "achieved_flops": 0.0,
                      "counters": {"jobs_completed": 9},
                      "hists": {"net_rtt_secs": {"count": 2, "sum_secs": 0.002,
                                "buckets": [[19, 1], [21, 1]]}}},
              "net": {"counters": {"net_bytes_in": 77}},
              "cache_hit_rate": 0.25,
              "queue_depth": 4
            }"#,
        )
        .unwrap();
        let mut e = Exposition::new();
        e.add_metrics_json(&doc, &[("backend", "2")]);
        let text = e.render();
        assert!(text.contains("fastmps_jobs_completed_total{backend=\"2\"} 9"));
        assert!(text.contains("fastmps_net_bytes_in_total{backend=\"2\"} 77"));
        assert!(text.contains("fastmps_queue_depth{backend=\"2\"} 4"));
        assert!(text.contains("fastmps_net_rtt_seconds_bucket{backend=\"2\",le=\"+Inf\"} 2"));
        assert!(text.contains("fastmps_net_rtt_seconds_count{backend=\"2\"} 2"));
        validate_exposition(&text).unwrap();
    }

    #[test]
    fn validator_rejects_broken_exposition() {
        // Sample without TYPE.
        assert!(validate_exposition("fastmps_x_total 1\n").is_err());
        // TYPE without HELP.
        assert!(validate_exposition("# TYPE fastmps_x_total counter\nfastmps_x_total 1\n").is_err());
        // Counter not ending _total.
        let t = "# HELP fastmps_x c\n# TYPE fastmps_x counter\nfastmps_x 1\n";
        assert!(validate_exposition(t).is_err());
        // Histogram with decreasing cumulative counts.
        let t = "# HELP fastmps_w_seconds h\n# TYPE fastmps_w_seconds histogram\n\
                 fastmps_w_seconds_bucket{le=\"0.1\"} 5\n\
                 fastmps_w_seconds_bucket{le=\"1\"} 3\n\
                 fastmps_w_seconds_bucket{le=\"+Inf\"} 3\n\
                 fastmps_w_seconds_sum 1\nfastmps_w_seconds_count 3\n";
        assert!(validate_exposition(t).is_err());
        // +Inf mismatch with _count.
        let t = "# HELP fastmps_w_seconds h\n# TYPE fastmps_w_seconds histogram\n\
                 fastmps_w_seconds_bucket{le=\"+Inf\"} 3\n\
                 fastmps_w_seconds_sum 1\nfastmps_w_seconds_count 4\n";
        assert!(validate_exposition(t).is_err());
        // Bad label charset.
        let t = "# HELP fastmps_g h\n# TYPE fastmps_g gauge\nfastmps_g{0bad=\"x\"} 1\n";
        assert!(validate_exposition(t).is_err());
        // A well-formed document passes.
        let t = "# HELP fastmps_g h\n# TYPE fastmps_g gauge\nfastmps_g{backend=\"0\"} 1\n";
        validate_exposition(t).unwrap();
    }

    #[test]
    fn committed_fixture_passes_the_rust_validator() {
        // The same file the toolchain-free CI gate checks — keep the
        // two validators agreeing on it.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/exposition.fixture.prom");
        let text = std::fs::read_to_string(path).expect("read docs/exposition.fixture.prom");
        validate_exposition(&text).unwrap();
        assert!(text.contains("backend=\""), "fixture should exercise fleet labels");
    }
}
