//! Telemetry plane: continuous monitoring for servers, routers, and fleets.
//!
//! Where the flight recorder (`trace`) answers "what happened to this
//! job" and `fastmps metrics` answers "what are the lifetime totals",
//! this module answers "what is happening *right now*, and what did the
//! last ten minutes look like". Three pieces, all zero-dependency:
//!
//! - [`TsRing`]: a fixed-capacity time-series ring. A background
//!   sampler in `serve` and `route` snapshots selected counters,
//!   gauges, and histogram quantiles into it on the telemetry interval
//!   (`NetConfig::telemetry_interval_ms`, default 1 s). The snapshot
//!   hot path never allocates. Rates (jobs/s, bytes/s, steps/s) are
//!   derived from adjacent-sample deltas at render time, so the ring
//!   stores only monotonic raw values and stays merge-trivial.
//! - [`prom`]: a Prometheus text-format exposition renderer over the
//!   `fastmps metrics --json` document, served at `GET /metrics` by the
//!   minimal HTTP/1.0 responder in [`http`] when `--metrics-listen` is
//!   set. The router renders its scraped backends with `backend="N"`
//!   labels for a single fleet-wide scrape target.
//! - [`top`]: the `fastmps top` terminal dashboard, rendered from ring
//!   history fetched over the `telemetry` FMPN op.

pub mod http;
pub mod prom;
pub mod top;

use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

/// Ring capacity used by the built-in samplers: ten minutes of history
/// at the default 1 s interval. Deliberately a constant, not a config
/// knob — the ring is ~100 B/slot, and a fixed horizon keeps the
/// `telemetry` op reply bounded.
pub const RING_CAPACITY: usize = 600;

/// Wall-clock unix milliseconds (the timestamp base for samples, so
/// rings from different processes line up in one dashboard).
pub fn now_unix_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// One point-in-time sample. Fixed size, `Copy`, no heap — writing one
/// into a [`TsRing`] is a lock plus a handful of stores.
///
/// Counter fields (`jobs_*`, `samples_done`, `steps`, `net_bytes_*`)
/// are cumulative lifetime values; [`rates`] turns two adjacent samples
/// into per-second deltas. Quantile fields are `None` while the
/// backing histogram is empty — an empty window is null, never zero.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TsSample {
    /// Wall-clock unix milliseconds at snapshot time.
    pub unix_ms: u64,
    /// Live (non-terminal) jobs in the queue; routed-and-unfinished
    /// jobs when sampled by a router.
    pub queue_depth: u64,
    /// Batches formed and waiting for (or on) a worker.
    pub inflight_batches: u64,
    /// Lifetime store-cache hit rate, `None` before the first lookup
    /// (and always `None` on a router, which has no cache).
    pub cache_hit_rate: Option<f64>,
    /// Lifetime jobs admitted (router: jobs placed on a backend).
    pub jobs_submitted: u64,
    /// Lifetime jobs completed.
    pub jobs_completed: u64,
    /// Lifetime jobs failed (router: jobs dropped in drain).
    pub jobs_failed: u64,
    /// Lifetime samples produced (`keys::SAMPLES`).
    pub samples_done: u64,
    /// Lifetime per-site step executions (`keys::STEPS`).
    pub steps: u64,
    /// Lifetime bytes read off / written to sockets.
    pub net_bytes_in: u64,
    pub net_bytes_out: u64,
    /// Queue-wait quantiles, seconds (admission → first batch).
    pub queue_wait_p50: Option<f64>,
    pub queue_wait_p99: Option<f64>,
    /// Control-frame RTT quantiles, seconds (router → backend legs;
    /// `None` on a plain server, which observes no RTT of its own).
    pub rtt_p50: Option<f64>,
    pub rtt_p99: Option<f64>,
}

fn num_or_null(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

fn opt_f64(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(|v| v.as_f64())
}

fn u64_of(j: &Json, key: &str) -> u64 {
    opt_f64(j, key).map(|v| v.max(0.0) as u64).unwrap_or(0)
}

impl TsSample {
    /// Wire form for the `telemetry` op. Duration fields follow the
    /// metrics-JSON conventions: `_secs` suffix, null when unobserved.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("unix_ms", Json::Num(self.unix_ms as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("inflight_batches", Json::Num(self.inflight_batches as f64)),
            ("cache_hit_rate", num_or_null(self.cache_hit_rate)),
            ("jobs_submitted", Json::Num(self.jobs_submitted as f64)),
            ("jobs_completed", Json::Num(self.jobs_completed as f64)),
            ("jobs_failed", Json::Num(self.jobs_failed as f64)),
            ("samples_done", Json::Num(self.samples_done as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("net_bytes_in", Json::Num(self.net_bytes_in as f64)),
            ("net_bytes_out", Json::Num(self.net_bytes_out as f64)),
            ("queue_wait_p50_secs", num_or_null(self.queue_wait_p50)),
            ("queue_wait_p99_secs", num_or_null(self.queue_wait_p99)),
            ("rtt_p50_secs", num_or_null(self.rtt_p50)),
            ("rtt_p99_secs", num_or_null(self.rtt_p99)),
        ])
    }

    /// Parse one wire sample back (the `top` client side). Missing
    /// fields read as zero/null so the format can grow.
    pub fn from_json(j: &Json) -> TsSample {
        TsSample {
            unix_ms: u64_of(j, "unix_ms"),
            queue_depth: u64_of(j, "queue_depth"),
            inflight_batches: u64_of(j, "inflight_batches"),
            cache_hit_rate: opt_f64(j, "cache_hit_rate"),
            jobs_submitted: u64_of(j, "jobs_submitted"),
            jobs_completed: u64_of(j, "jobs_completed"),
            jobs_failed: u64_of(j, "jobs_failed"),
            samples_done: u64_of(j, "samples_done"),
            steps: u64_of(j, "steps"),
            net_bytes_in: u64_of(j, "net_bytes_in"),
            net_bytes_out: u64_of(j, "net_bytes_out"),
            queue_wait_p50: opt_f64(j, "queue_wait_p50_secs"),
            queue_wait_p99: opt_f64(j, "queue_wait_p99_secs"),
            rtt_p50: opt_f64(j, "rtt_p50_secs"),
            rtt_p99: opt_f64(j, "rtt_p99_secs"),
        }
    }

    /// Build a sample from a scraped `metrics` op document (the fleet
    /// poller's path: the router has a backend's JSON, not its
    /// internals). Absent fields read as zero/null.
    pub fn from_metrics_json(doc: &Json, unix_ms: u64) -> TsSample {
        let empty = Json::obj(vec![]);
        let run = doc.get("run").unwrap_or(&empty);
        let counters = run.get("counters").unwrap_or(&empty);
        let net = doc.get("net").and_then(|n| n.get("counters"));
        let net = net.unwrap_or(&empty);
        let qw = run.get("hists").and_then(|h| h.get("queue_wait_secs"));
        let rtt = run.get("hists").and_then(|h| h.get("net_rtt_secs"));
        TsSample {
            unix_ms,
            queue_depth: u64_of(doc, "queue_depth").max(u64_of(doc, "jobs_in_flight")),
            inflight_batches: u64_of(doc, "inflight_batches"),
            cache_hit_rate: opt_f64(doc, "cache_hit_rate"),
            jobs_submitted: u64_of(counters, "jobs_submitted"),
            jobs_completed: u64_of(counters, "jobs_completed"),
            jobs_failed: u64_of(counters, "jobs_failed"),
            samples_done: u64_of(counters, "samples"),
            steps: u64_of(counters, "steps"),
            net_bytes_in: u64_of(net, "net_bytes_in"),
            net_bytes_out: u64_of(net, "net_bytes_out"),
            queue_wait_p50: qw.and_then(|h| opt_f64(h, "p50_secs")),
            queue_wait_p99: qw.and_then(|h| opt_f64(h, "p99_secs")),
            rtt_p50: rtt.and_then(|h| opt_f64(h, "p50_secs")),
            rtt_p99: rtt.and_then(|h| opt_f64(h, "p99_secs")),
        }
    }
}

/// Per-second rates derived from two adjacent samples.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TsRates {
    pub jobs_per_sec: f64,
    pub samples_per_sec: f64,
    pub steps_per_sec: f64,
    pub bytes_in_per_sec: f64,
    pub bytes_out_per_sec: f64,
}

/// Delta rates between `prev` and `next`. Counters are monotonic per
/// process; a counter that went backwards (process restart between
/// samples) clamps to zero rather than reporting a negative rate.
pub fn rates(prev: &TsSample, next: &TsSample) -> TsRates {
    let dt_ms = next.unix_ms.saturating_sub(prev.unix_ms);
    if dt_ms == 0 {
        return TsRates::default();
    }
    let dt = dt_ms as f64 / 1000.0;
    let d = |a: u64, b: u64| b.saturating_sub(a) as f64 / dt;
    TsRates {
        jobs_per_sec: d(prev.jobs_completed, next.jobs_completed),
        samples_per_sec: d(prev.samples_done, next.samples_done),
        steps_per_sec: d(prev.steps, next.steps),
        bytes_in_per_sec: d(prev.net_bytes_in, next.net_bytes_in),
        bytes_out_per_sec: d(prev.net_bytes_out, next.net_bytes_out),
    }
}

struct RingInner {
    /// Preallocated to capacity at construction; never grows.
    slots: Vec<TsSample>,
    /// Next write index.
    head: usize,
    /// Total samples ever written (so `len = min(written, cap)`).
    written: u64,
}

/// Fixed-capacity time-series ring. Writers call [`TsRing::snapshot`]
/// — a lock and a slot store, no allocation — and the ring overwrites
/// its oldest sample when full. Readers get history oldest-first.
pub struct TsRing {
    inner: Mutex<RingInner>,
}

impl TsRing {
    pub fn new(capacity: usize) -> TsRing {
        let cap = capacity.max(2);
        TsRing {
            inner: Mutex::new(RingInner {
                slots: vec![TsSample::default(); cap],
                head: 0,
                written: 0,
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    /// Samples currently held (saturates at capacity).
    pub fn len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        (g.written as usize).min(g.slots.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Record one sample. This is the hot path the background sampler
    /// hits every interval: it never allocates (the slot vec is
    /// preallocated and `TsSample` is `Copy`).
    pub fn snapshot(&self, s: TsSample) {
        let mut g = self.inner.lock().unwrap();
        let cap = g.slots.len();
        let head = g.head;
        g.slots[head] = s;
        g.head = (head + 1) % cap;
        g.written += 1;
    }

    /// Most recent sample, if any.
    pub fn latest(&self) -> Option<TsSample> {
        let g = self.inner.lock().unwrap();
        if g.written == 0 {
            return None;
        }
        let cap = g.slots.len();
        Some(g.slots[(g.head + cap - 1) % cap])
    }

    /// The two most recent samples `(previous, latest)`, for rates.
    pub fn last_two(&self) -> Option<(TsSample, TsSample)> {
        let g = self.inner.lock().unwrap();
        if g.written < 2 {
            return None;
        }
        let cap = g.slots.len();
        let last = (g.head + cap - 1) % cap;
        let prev = (g.head + cap - 2) % cap;
        Some((g.slots[prev], g.slots[last]))
    }

    /// Copy history, oldest first, into `out` (cleared first). With
    /// `out.capacity() >= len` this does not allocate either.
    pub fn history_into(&self, out: &mut Vec<TsSample>) {
        out.clear();
        let g = self.inner.lock().unwrap();
        let cap = g.slots.len();
        let len = (g.written as usize).min(cap);
        let start = if g.written as usize > cap { g.head } else { 0 };
        for i in 0..len {
            out.push(g.slots[(start + i) % cap]);
        }
    }

    pub fn history(&self) -> Vec<TsSample> {
        let mut out = Vec::with_capacity(self.capacity());
        self.history_into(&mut out);
        out
    }

    /// Ring history as a JSON array, oldest first (the `telemetry` op
    /// reply body).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.history().iter().map(|s| s.to_json()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: u64, jobs: u64) -> TsSample {
        TsSample {
            unix_ms: t,
            queue_depth: 3,
            inflight_batches: 1,
            cache_hit_rate: Some(0.5),
            jobs_submitted: jobs + 2,
            jobs_completed: jobs,
            jobs_failed: 1,
            samples_done: jobs * 100,
            steps: jobs * 1000,
            net_bytes_in: jobs * 10,
            net_bytes_out: jobs * 20,
            queue_wait_p50: Some(0.001),
            queue_wait_p99: Some(0.1),
            rtt_p50: None,
            rtt_p99: None,
        }
    }

    #[test]
    fn ring_holds_and_rolls_oldest_first() {
        let ring = TsRing::new(4);
        assert!(ring.is_empty());
        assert_eq!(ring.latest(), None);
        for t in 0..3 {
            ring.snapshot(sample(t, t));
        }
        assert_eq!(ring.len(), 3);
        let h = ring.history();
        assert_eq!(h.iter().map(|s| s.unix_ms).collect::<Vec<_>>(), vec![0, 1, 2]);
        // Overflow: oldest rolls off, order stays oldest-first.
        for t in 3..10 {
            ring.snapshot(sample(t, t));
        }
        assert_eq!(ring.len(), 4);
        let h = ring.history();
        assert_eq!(h.iter().map(|s| s.unix_ms).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert_eq!(ring.latest().unwrap().unix_ms, 9);
        let (prev, last) = ring.last_two().unwrap();
        assert_eq!((prev.unix_ms, last.unix_ms), (8, 9));
    }

    #[test]
    fn snapshot_is_allocation_free() {
        let ring = TsRing::new(RING_CAPACITY);
        // Warm: the slot vec is preallocated in new(), but give the
        // allocator one pass anyway before measuring.
        ring.snapshot(sample(1, 1));
        let mut clean = false;
        for t in 0..128u64 {
            let before = crate::util::alloc::allocation_count();
            ring.snapshot(sample(t + 2, t));
            if crate::util::alloc::allocation_count() == before {
                clean = true;
                break;
            }
        }
        assert!(clean, "TsRing::snapshot allocated in every window");
    }

    #[test]
    fn history_into_reuses_capacity_without_allocating() {
        let ring = TsRing::new(8);
        for t in 0..20 {
            ring.snapshot(sample(t, t));
        }
        let mut out = Vec::with_capacity(ring.capacity());
        ring.history_into(&mut out); // warm
        let mut clean = false;
        for _ in 0..128 {
            let before = crate::util::alloc::allocation_count();
            ring.history_into(&mut out);
            if crate::util::alloc::allocation_count() == before {
                clean = true;
                break;
            }
        }
        assert!(clean, "history_into allocated with sufficient capacity");
        assert_eq!(out.len(), 8);
        assert_eq!(out[0].unix_ms, 12);
    }

    #[test]
    fn sample_json_round_trip() {
        let s = sample(1234, 7);
        let j = Json::parse(&s.to_json().dump()).unwrap();
        assert_eq!(TsSample::from_json(&j), s);
        // Null quantiles survive the trip as None.
        let mut e = TsSample::default();
        e.unix_ms = 5;
        let j = Json::parse(&e.to_json().dump()).unwrap();
        assert_eq!(j.get("queue_wait_p50_secs"), Some(&Json::Null));
        assert_eq!(TsSample::from_json(&j), e);
    }

    #[test]
    fn rates_from_deltas() {
        let a = sample(1000, 10);
        let b = sample(3000, 20); // 2 s apart, +10 jobs
        let r = rates(&a, &b);
        assert!((r.jobs_per_sec - 5.0).abs() < 1e-12);
        assert!((r.samples_per_sec - 500.0).abs() < 1e-9);
        assert!((r.steps_per_sec - 5000.0).abs() < 1e-9);
        assert!((r.bytes_in_per_sec - 50.0).abs() < 1e-12);
        assert!((r.bytes_out_per_sec - 100.0).abs() < 1e-12);
        // Zero dt and backwards counters both clamp to zero.
        assert_eq!(rates(&a, &a), TsRates::default());
        assert_eq!(rates(&b, &a).jobs_per_sec, 0.0);
    }

    #[test]
    fn sample_from_metrics_document() {
        let doc = Json::parse(
            r#"{
              "config": {},
              "run": {
                "phases": {}, "achieved_flops": 0.0,
                "counters": {"jobs_submitted": 9, "jobs_completed": 7, "jobs_failed": 1,
                             "samples": 700, "steps": 7000},
                "hists": {"queue_wait_secs": {"count": 7, "sum_secs": 0.7,
                          "p50_secs": 0.01, "p99_secs": 0.2, "buckets": [[20, 7]]}}
              },
              "net": {"counters": {"net_bytes_in": 123, "net_bytes_out": 456}},
              "cache_hit_rate": 0.9,
              "queue_depth": 2,
              "inflight_batches": 1
            }"#,
        )
        .unwrap();
        let s = TsSample::from_metrics_json(&doc, 42);
        assert_eq!(s.unix_ms, 42);
        assert_eq!(s.queue_depth, 2);
        assert_eq!(s.inflight_batches, 1);
        assert_eq!(s.jobs_completed, 7);
        assert_eq!(s.samples_done, 700);
        assert_eq!(s.net_bytes_out, 456);
        assert_eq!(s.cache_hit_rate, Some(0.9));
        assert_eq!(s.queue_wait_p99, Some(0.2));
        assert_eq!(s.rtt_p50, None);
        // A router document: jobs_in_flight stands in for queue depth.
        let doc = Json::parse(
            r#"{"run": {"counters": {"router_submits": 3}}, "jobs_in_flight": 5}"#,
        )
        .unwrap();
        assert_eq!(TsSample::from_metrics_json(&doc, 1).queue_depth, 5);
    }
}
