//! Minimal HTTP/1.0 `GET /metrics` responder.
//!
//! Just enough HTTP for a Prometheus scrape or `curl`: one accept
//! loop, requests served inline (a scrape is a read of one request
//! line and one buffered write), `Connection: close` on every reply.
//! Deliberately not a web server — no keep-alive, no chunking, no
//! routing beyond `/metrics`. Runs on its own listener so the metrics
//! plane shares nothing with the FMPN data plane except the process.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::log_debug;
use crate::util::error::{Error, Result};

/// Renders the current exposition body on demand, once per scrape.
pub type RenderFn = Arc<dyn Fn() -> String + Send + Sync>;

struct HttpInner {
    stop: AtomicBool,
}

/// A running `/metrics` endpoint. Dropping it (or calling
/// [`MetricsHttp::shutdown`]) stops the accept loop and joins it.
pub struct MetricsHttp {
    addr: SocketAddr,
    inner: Arc<HttpInner>,
    accept: Option<JoinHandle<()>>,
}

impl MetricsHttp {
    /// Bind `listen` (`host:port`; port 0 picks a free port, see
    /// [`MetricsHttp::local_addr`]) and serve `render()` at
    /// `GET /metrics` until shutdown.
    pub fn start(listen: &str, render: RenderFn) -> Result<MetricsHttp> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| Error::io(format!("telemetry http: bind {listen}"), e))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::io("telemetry http: local_addr", e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::io("telemetry http: set_nonblocking", e))?;
        let inner = Arc::new(HttpInner { stop: AtomicBool::new(false) });
        let accept = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("fastmps-metrics-http".into())
                .spawn(move || accept_loop(listener, inner, render))
                .map_err(|e| Error::io("telemetry http: spawn", e))?
        };
        Ok(MetricsHttp { addr, inner, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsHttp {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, inner: Arc<HttpInner>, render: RenderFn) {
    while !inner.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                if let Err(e) = serve_one(stream, &render) {
                    log_debug!("telemetry http: scrape failed: {e}");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                log_debug!("telemetry http: accept error: {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

fn serve_one(mut stream: TcpStream, render: &RenderFn) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(1000)))?;
    stream.set_write_timeout(Some(Duration::from_millis(2000)))?;
    // Read until the blank line ending the request head (or 4 KiB,
    // whichever first) — only the request line matters.
    let mut buf = [0u8; 4096];
    let mut used = 0usize;
    loop {
        if used == buf.len() {
            break;
        }
        let n = stream.read(&mut buf[used..])?;
        if n == 0 {
            break;
        }
        used += n;
        if buf[..used].windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf[..used]);
    let request_line = head.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let (status, body) = if method != "GET" {
        ("405 Method Not Allowed", "method not allowed\n".to_string())
    } else if path == "/metrics" {
        ("200 OK", render())
    } else if path == "/" {
        ("200 OK", "fastmps telemetry endpoint; scrape /metrics\n".to_string())
    } else {
        ("404 Not Found", "not found; scrape /metrics\n".to_string())
    };
    let reply = format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(reply.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write!(s, "GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_elsewhere() {
        let render: RenderFn = Arc::new(|| "# HELP fastmps_up u\n# TYPE fastmps_up gauge\nfastmps_up 1\n".to_string());
        let mut srv = MetricsHttp::start("127.0.0.1:0", render).unwrap();
        let addr = srv.local_addr();
        let ok = get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.0 200 OK\r\n"), "got: {ok}");
        assert!(ok.contains("Content-Type: text/plain; version=0.0.4"));
        assert!(ok.ends_with("fastmps_up 1\n"));
        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.0 404"));
        // Each scrape re-renders: the closure runs per request.
        let again = get(addr, "/metrics");
        assert!(again.contains("fastmps_up 1"));
        srv.shutdown();
        // Idempotent shutdown; the port is released after join.
        srv.shutdown();
    }
}
