//! Dense row-major complex matrices and rank-3 tensors.

use crate::util::num::Float;

use super::complex::Complex;
use crate::util::error::{Error, Result};

/// Row-major complex matrix `(rows, cols)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat<T> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<Complex<T>>,
}

/// Borrowed row-major matrix view — the zero-copy counterpart of [`Mat`].
///
/// The hot sampling path views a `Tensor3` Γ as a `(χ_l, χ_r·d)` matrix
/// without cloning its data ([`Tensor3::as_mat_ref`]); the GEMM kernels
/// accept views so that reshape costs nothing.
#[derive(Debug, Clone, Copy)]
pub struct MatRef<'a, T> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [Complex<T>],
}

impl<'a, T> MatRef<'a, T> {
    pub fn new(rows: usize, cols: usize, data: &'a [Complex<T>]) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "MatRef::new: {}×{} != {} elements",
                rows,
                cols,
                data.len()
            )));
        }
        Ok(MatRef { rows, cols, data })
    }

    #[inline]
    pub fn row(&self, r: usize) -> &'a [Complex<T>] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

impl<T: Float + std::ops::AddAssign> Mat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![Complex::zero(); rows * cols],
        }
    }

    /// Borrowed view of the whole matrix. (Named `view`, not `as_ref`, to
    /// stay clear of `AsRef`.)
    #[inline]
    pub fn view(&self) -> MatRef<'_, T> {
        MatRef {
            rows: self.rows,
            cols: self.cols,
            data: &self.data,
        }
    }

    /// Reshape in place to `(rows, cols)` with every entry zeroed. Only
    /// grows the backing buffer when capacity is insufficient — the
    /// workspace-reuse contract of the step engines relies on this being
    /// allocation-free at steady state.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        let n = rows * cols;
        self.data.clear();
        self.data.resize(n, Complex::zero());
    }

    /// Reshape in place WITHOUT zeroing: entry values are unspecified
    /// (stale) and the caller must overwrite every one. For hot-path
    /// consumers that fully rewrite the buffer anyway ([`reset`]'s
    /// zero-fill would be a wasted full pass there).
    ///
    /// [`reset`]: Mat::reset
    pub fn reshape(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        let n = rows * cols;
        if self.data.len() < n {
            self.data.resize(n, Complex::zero());
        } else {
            self.data.truncate(n);
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<Complex<T>>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "Mat::from_vec: {}×{} != {} elements",
                rows,
                cols,
                data.len()
            )));
        }
        Ok(Mat { rows, cols, data })
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = Complex::one();
        }
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[Complex<T>] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [Complex<T>] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> T {
        let mut acc = T::zero();
        for z in &self.data {
            acc += z.norm_sq();
        }
        acc.sqrt()
    }

    /// Max |z| over all entries.
    pub fn max_abs(&self) -> T {
        let mut m = T::zero();
        for z in &self.data {
            let a = z.norm_sq();
            if a > m {
                m = a;
            }
        }
        m.sqrt()
    }

    /// Conjugate transpose.
    pub fn dagger(&self) -> Mat<T> {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)].conj();
            }
        }
        out
    }

    /// Scale every entry by a real factor.
    pub fn scale_in_place(&mut self, s: T) {
        for z in &mut self.data {
            *z = z.scale(s);
        }
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|z| z.is_finite())
    }
}

impl<T> std::ops::Index<(usize, usize)> for Mat<T> {
    type Output = Complex<T>;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &Complex<T> {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl<T> std::ops::IndexMut<(usize, usize)> for Mat<T> {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut Complex<T> {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

/// Dense rank-3 tensor `(d0, d1, d2)`, row-major (last index fastest).
///
/// For an MPS site tensor `Γ` the layout is `(χ_l, χ_r, d)`: the physical
/// index is innermost so the bond contraction sees contiguous `χ_r × d`
/// panels — the same layout the L1 Pallas kernel and the Γ store use.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor3<T> {
    pub d0: usize,
    pub d1: usize,
    pub d2: usize,
    pub data: Vec<Complex<T>>,
}

impl<T: Float + std::ops::AddAssign> Tensor3<T> {
    pub fn zeros(d0: usize, d1: usize, d2: usize) -> Self {
        Tensor3 {
            d0,
            d1,
            d2,
            data: vec![Complex::zero(); d0 * d1 * d2],
        }
    }

    pub fn from_vec(d0: usize, d1: usize, d2: usize, data: Vec<Complex<T>>) -> Result<Self> {
        if data.len() != d0 * d1 * d2 {
            return Err(Error::shape(format!(
                "Tensor3::from_vec: {}×{}×{} != {} elements",
                d0,
                d1,
                d2,
                data.len()
            )));
        }
        Ok(Tensor3 { d0, d1, d2, data })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize, k: usize) -> Complex<T> {
        debug_assert!(i < self.d0 && j < self.d1 && k < self.d2);
        self.data[(i * self.d1 + j) * self.d2 + k]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize, k: usize) -> &mut Complex<T> {
        debug_assert!(i < self.d0 && j < self.d1 && k < self.d2);
        &mut self.data[(i * self.d1 + j) * self.d2 + k]
    }

    /// Contiguous `(d1 × d2)` panel at first index `i` — a Γ row over the
    /// left bond.
    #[inline]
    pub fn panel(&self, i: usize) -> &[Complex<T>] {
        let s = self.d1 * self.d2;
        &self.data[i * s..(i + 1) * s]
    }

    /// View the tensor as a `(d0, d1*d2)` matrix without copying shapes
    /// (used to feed the split-K GEMM).
    pub fn as_matrix(&self) -> Mat<T>
    where
        Complex<T>: Clone,
    {
        Mat {
            rows: self.d0,
            cols: self.d1 * self.d2,
            data: self.data.clone(),
        }
    }

    /// Zero-copy `(d0, d1*d2)` matrix view — how the bond contraction
    /// consumes a prepared Γ without cloning it.
    #[inline]
    pub fn as_mat_ref(&self) -> MatRef<'_, T> {
        MatRef {
            rows: self.d0,
            cols: self.d1 * self.d2,
            data: &self.data,
        }
    }

    /// Reshape in place to `(d0, d1, d2)`, zero-filled; grows the backing
    /// buffer only when capacity is insufficient (see [`Mat::reset`]).
    pub fn reset(&mut self, d0: usize, d1: usize, d2: usize) {
        self.d0 = d0;
        self.d1 = d1;
        self.d2 = d2;
        let n = d0 * d1 * d2;
        self.data.clear();
        self.data.resize(n, Complex::zero());
    }

    /// Reshape in place WITHOUT zeroing: retained entry values are stale
    /// and the caller must overwrite every one (the β=0 overwrite GEMM
    /// does exactly that — see [`Mat::reshape`]).
    pub fn reshape(&mut self, d0: usize, d1: usize, d2: usize) {
        self.d0 = d0;
        self.d1 = d1;
        self.d2 = d2;
        let n = d0 * d1 * d2;
        if self.data.len() < n {
            self.data.resize(n, Complex::zero());
        } else {
            self.data.truncate(n);
        }
    }

    /// Slice `rows ∈ [lo, hi)` of the first axis (a χ_l shard for tensor
    /// parallelism). Copies.
    pub fn slice_d0(&self, lo: usize, hi: usize) -> Result<Tensor3<T>> {
        if lo > hi || hi > self.d0 {
            return Err(Error::shape(format!(
                "slice_d0 [{lo},{hi}) out of range for d0={}",
                self.d0
            )));
        }
        let s = self.d1 * self.d2;
        Ok(Tensor3 {
            d0: hi - lo,
            d1: self.d1,
            d2: self.d2,
            data: self.data[lo * s..hi * s].to_vec(),
        })
    }

    /// Slice `cols ∈ [lo, hi)` of the *second* axis (χ_r shard — the
    /// double-site scheme's even-site split). Copies.
    pub fn slice_d1(&self, lo: usize, hi: usize) -> Result<Tensor3<T>> {
        if lo > hi || hi > self.d1 {
            return Err(Error::shape(format!(
                "slice_d1 [{lo},{hi}) out of range for d1={}",
                self.d1
            )));
        }
        let mut out = Tensor3::zeros(self.d0, hi - lo, self.d2);
        for i in 0..self.d0 {
            for (jj, j) in (lo..hi).enumerate() {
                for k in 0..self.d2 {
                    *out.at_mut(i, jj, k) = self.at(i, j, k);
                }
            }
        }
        Ok(out)
    }

    pub fn max_abs(&self) -> T {
        let mut m = T::zero();
        for z in &self.data {
            let a = z.norm_sq();
            if a > m {
                m = a;
            }
        }
        m.sqrt()
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|z| z.is_finite())
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::C64;

    #[test]
    fn mat_indexing_row_major() {
        let mut m: Mat<f64> = Mat::zeros(2, 3);
        m[(1, 2)] = C64::new(5.0, 0.0);
        assert_eq!(m.data[5], C64::new(5.0, 0.0));
        assert_eq!(m.row(1)[2], C64::new(5.0, 0.0));
    }

    #[test]
    fn from_vec_shape_checked() {
        assert!(Mat::<f64>::from_vec(2, 2, vec![C64::zero(); 3]).is_err());
        assert!(Tensor3::<f64>::from_vec(2, 2, 2, vec![C64::zero(); 8]).is_ok());
    }

    #[test]
    fn dagger_involution() {
        let mut m: Mat<f64> = Mat::zeros(2, 3);
        m[(0, 1)] = C64::new(1.0, 2.0);
        m[(1, 2)] = C64::new(-3.0, 0.5);
        let dd = m.dagger().dagger();
        assert_eq!(m, dd);
        assert_eq!(m.dagger()[(1, 0)], C64::new(1.0, -2.0));
    }

    #[test]
    fn tensor3_panels_and_slices() {
        let mut t: Tensor3<f64> = Tensor3::zeros(3, 2, 2);
        for i in 0..3 {
            for j in 0..2 {
                for k in 0..2 {
                    *t.at_mut(i, j, k) = C64::new((100 * i + 10 * j + k) as f64, 0.0);
                }
            }
        }
        assert_eq!(t.panel(1)[0], C64::new(100.0, 0.0));
        let s = t.slice_d0(1, 3).unwrap();
        assert_eq!(s.d0, 2);
        assert_eq!(s.at(0, 1, 1), C64::new(111.0, 0.0));
        let s1 = t.slice_d1(1, 2).unwrap();
        assert_eq!(s1.d1, 1);
        assert_eq!(s1.at(2, 0, 0), C64::new(210.0, 0.0));
        assert!(t.slice_d0(2, 4).is_err());
        assert!(t.slice_d1(3, 2).is_err());
    }

    #[test]
    fn mat_ref_views_share_data() {
        let mut t: Tensor3<f64> = Tensor3::zeros(2, 3, 2);
        *t.at_mut(1, 2, 1) = C64::new(7.0, -1.0);
        let v = t.as_mat_ref();
        assert_eq!((v.rows, v.cols), (2, 6));
        assert_eq!(v.row(1)[5], C64::new(7.0, -1.0));
        let m: Mat<f64> = Mat::zeros(2, 2);
        assert_eq!(m.view().rows, 2);
        assert!(MatRef::new(2, 2, &t.data).is_err());
    }

    #[test]
    fn reset_reuses_capacity() {
        let mut m: Mat<f64> = Mat::zeros(4, 4);
        m[(0, 0)] = C64::new(1.0, 0.0);
        let cap = m.data.capacity();
        let ptr = m.data.as_ptr();
        m.reset(2, 3);
        assert_eq!((m.rows, m.cols, m.data.len()), (2, 3, 6));
        assert_eq!(m[(0, 0)], C64::zero(), "reset zero-fills");
        assert_eq!(m.data.capacity(), cap);
        assert_eq!(m.data.as_ptr(), ptr, "no reallocation when shrinking");
        m[(0, 0)] = C64::new(2.0, 0.0);
        m.reshape(1, 4);
        assert_eq!((m.rows, m.cols, m.data.len()), (1, 4, 4));
        assert_eq!(m[(0, 0)], C64::new(2.0, 0.0), "reshape keeps stale values");
        assert_eq!(m.data.as_ptr(), ptr);
        let mut t: Tensor3<f64> = Tensor3::zeros(2, 2, 2);
        t.reset(1, 2, 3);
        assert_eq!((t.d0, t.d1, t.d2, t.data.len()), (1, 2, 3, 6));
    }

    #[test]
    fn norms() {
        let m = Mat::from_vec(
            1,
            2,
            vec![C64::new(3.0, 0.0), C64::new(0.0, 4.0)],
        )
        .unwrap();
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
        assert!((m.max_abs() - 4.0).abs() < 1e-12);
    }
}
