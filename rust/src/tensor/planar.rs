//! Split-real (planar / SoA) complex tensors for the SIMD hot path.
//!
//! The native kernels historically walk interleaved `Complex<T>` pairs
//! (AoS), which defeats autovectorization: every lane-wide load pulls
//! alternating re/im values that must be shuffled before the FMA. The
//! planar layout stores the real and imaginary parts in two separate
//! contiguous planes with identical row-major indexing, so the innermost
//! kernel loops become straight-line f32/f64 chains the compiler (or the
//! explicit `core::arch` microkernel behind the `simd` feature) vectorizes
//! directly.
//!
//! Element `(i, j)` of a [`PlanarMat`] lives at `re[i * cols + j]` /
//! `im[i * cols + j]` — the same linear index as the interleaved
//! [`Mat`](super::Mat), just split across two planes. Conversions are
//! therefore pure plane splits/merges in index order, which is what keeps
//! the planar kernels bit-identical to the interleaved ones (see
//! `docs/PERF.md`).

use super::complex::Complex;
use super::dense::{Mat, Tensor3};
use crate::util::num::Float;

/// Dense `(rows, cols)` matrix with split re/im planes.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanarMat<T> {
    pub rows: usize,
    pub cols: usize,
    pub re: Vec<T>,
    pub im: Vec<T>,
}

impl<T: Float> PlanarMat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        PlanarMat {
            rows,
            cols,
            re: vec![T::zero(); rows * cols],
            im: vec![T::zero(); rows * cols],
        }
    }

    /// Resize to `(rows, cols)` WITHOUT zeroing retained elements — for
    /// buffers whose every element is overwritten before being read
    /// (e.g. the β=0 overwrite GEMM output). New elements are zero.
    pub fn reshape(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        let n = rows * cols;
        self.re.truncate(n);
        self.re.resize(n, T::zero());
        self.im.truncate(n);
        self.im.resize(n, T::zero());
    }

    /// Resize to `(rows, cols)` and zero-fill every element.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.reshape(rows, cols);
        self.re.fill(T::zero());
        self.im.fill(T::zero());
    }

    pub fn view(&self) -> PlanarMatRef<'_, T> {
        PlanarMatRef {
            rows: self.rows,
            cols: self.cols,
            re: &self.re,
            im: &self.im,
        }
    }

    pub fn row_re(&self, r: usize) -> &[T] {
        &self.re[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_im(&self, r: usize) -> &[T] {
        &self.im[r * self.cols..(r + 1) * self.cols]
    }

    /// Element `(i, j)` reassembled as a complex value (test/debug aid;
    /// the kernels never touch this).
    pub fn at(&self, i: usize, j: usize) -> Complex<T> {
        let idx = i * self.cols + j;
        Complex::new(self.re[idx], self.im[idx])
    }

    /// Split an interleaved matrix into planes, element by element in
    /// linear index order.
    pub fn from_interleaved(m: &Mat<T>) -> Self {
        let mut out = PlanarMat {
            rows: m.rows,
            cols: m.cols,
            re: Vec::with_capacity(m.data.len()),
            im: Vec::with_capacity(m.data.len()),
        };
        for z in &m.data {
            out.re.push(z.re);
            out.im.push(z.im);
        }
        out
    }

    /// Merge the planes back into an interleaved matrix.
    pub fn to_interleaved(&self) -> Mat<T> {
        let mut m = Mat::zeros(self.rows, self.cols);
        for (dst, (&re, &im)) in m.data.iter_mut().zip(self.re.iter().zip(&self.im)) {
            *dst = Complex::new(re, im);
        }
        m
    }

    /// Sum of plane capacities — the workspace high-water accounting unit
    /// used by `StepWorkspace::capacity_units`.
    pub fn capacity_units(&self) -> usize {
        self.re.capacity() + self.im.capacity()
    }
}

/// Borrowed planar matrix view (the planar analogue of
/// [`MatRef`](super::MatRef)).
#[derive(Debug, Clone, Copy)]
pub struct PlanarMatRef<'a, T> {
    pub rows: usize,
    pub cols: usize,
    pub re: &'a [T],
    pub im: &'a [T],
}

impl<'a, T: Float> PlanarMatRef<'a, T> {
    pub fn new(rows: usize, cols: usize, re: &'a [T], im: &'a [T]) -> Option<Self> {
        if re.len() != rows * cols || im.len() != rows * cols {
            return None;
        }
        Some(PlanarMatRef { rows, cols, re, im })
    }

    pub fn row_re(&self, r: usize) -> &'a [T] {
        &self.re[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_im(&self, r: usize) -> &'a [T] {
        &self.im[r * self.cols..(r + 1) * self.cols]
    }
}

/// Rank-3 tensor `(d0, d1, d2)` with split re/im planes; row-major with
/// `d2` fastest, matching [`Tensor3`].
#[derive(Debug, Clone, PartialEq)]
pub struct PlanarTensor3<T> {
    pub d0: usize,
    pub d1: usize,
    pub d2: usize,
    pub re: Vec<T>,
    pub im: Vec<T>,
}

impl<T: Float> PlanarTensor3<T> {
    pub fn zeros(d0: usize, d1: usize, d2: usize) -> Self {
        PlanarTensor3 {
            d0,
            d1,
            d2,
            re: vec![T::zero(); d0 * d1 * d2],
            im: vec![T::zero(); d0 * d1 * d2],
        }
    }

    /// Resize WITHOUT zeroing retained elements (see
    /// [`PlanarMat::reshape`]); new elements are zero.
    pub fn reshape(&mut self, d0: usize, d1: usize, d2: usize) {
        self.d0 = d0;
        self.d1 = d1;
        self.d2 = d2;
        let n = d0 * d1 * d2;
        self.re.truncate(n);
        self.re.resize(n, T::zero());
        self.im.truncate(n);
        self.im.resize(n, T::zero());
    }

    /// Resize and zero-fill.
    pub fn reset(&mut self, d0: usize, d1: usize, d2: usize) {
        self.reshape(d0, d1, d2);
        self.re.fill(T::zero());
        self.im.fill(T::zero());
    }

    /// Zero-copy `(d0, d1*d2)` matrix view — how the step contraction
    /// sees Γ, exactly like [`Tensor3::as_mat_ref`].
    pub fn as_mat_ref(&self) -> PlanarMatRef<'_, T> {
        PlanarMatRef {
            rows: self.d0,
            cols: self.d1 * self.d2,
            re: &self.re,
            im: &self.im,
        }
    }

    pub fn at(&self, i: usize, j: usize, k: usize) -> Complex<T> {
        let idx = (i * self.d1 + j) * self.d2 + k;
        Complex::new(self.re[idx], self.im[idx])
    }

    pub fn len(&self) -> usize {
        self.re.len()
    }

    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }

    /// Split an interleaved tensor into planes in linear index order.
    pub fn from_interleaved(t: &Tensor3<T>) -> Self {
        let mut out = PlanarTensor3 {
            d0: t.d0,
            d1: t.d1,
            d2: t.d2,
            re: Vec::with_capacity(t.data.len()),
            im: Vec::with_capacity(t.data.len()),
        };
        for z in &t.data {
            out.re.push(z.re);
            out.im.push(z.im);
        }
        out
    }

    /// Merge the planes back into an interleaved tensor.
    pub fn to_interleaved(&self) -> Tensor3<T> {
        let mut t = Tensor3::zeros(self.d0, self.d1, self.d2);
        for (dst, (&re, &im)) in t.data.iter_mut().zip(self.re.iter().zip(&self.im)) {
            *dst = Complex::new(re, im);
        }
        t
    }

    pub fn capacity_units(&self) -> usize {
        self.re.capacity() + self.im.capacity()
    }
}

impl<T: Float> Default for PlanarMat<T> {
    fn default() -> Self {
        PlanarMat {
            rows: 0,
            cols: 0,
            re: Vec::new(),
            im: Vec::new(),
        }
    }
}

impl<T: Float> Default for PlanarTensor3<T> {
    fn default() -> Self {
        PlanarTensor3 {
            d0: 0,
            d1: 0,
            d2: 0,
            re: Vec::new(),
            im: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::tensor::C64;

    #[test]
    fn interleaved_roundtrip_is_the_identity() {
        let mut rng = Xoshiro256::seed_from(11);
        let mut m = Mat::zeros(5, 7);
        for z in &mut m.data {
            *z = C64::new(rng.normal(), rng.normal());
        }
        let p = PlanarMat::from_interleaved(&m);
        assert_eq!(p.to_interleaved(), m);
        for i in 0..5 {
            for j in 0..7 {
                assert_eq!(p.at(i, j), m[(i, j)]);
            }
        }

        let mut t = Tensor3::zeros(3, 4, 2);
        for z in &mut t.data {
            *z = C64::new(rng.normal(), rng.normal());
        }
        let pt = PlanarTensor3::from_interleaved(&t);
        assert_eq!(pt.to_interleaved().data, t.data);
        assert_eq!(pt.at(2, 3, 1), *t.at(2, 3, 1));
    }

    #[test]
    fn reshape_keeps_capacity_and_reset_zeroes() {
        let mut p: PlanarMat<f32> = PlanarMat::zeros(8, 8);
        p.re[0] = 3.0;
        p.im[0] = -1.0;
        let cap = p.re.capacity();
        p.reshape(4, 4);
        assert_eq!((p.rows, p.cols), (4, 4));
        assert_eq!(p.re.capacity(), cap, "reshape must not shrink capacity");
        assert_eq!(p.re[0], 3.0, "reshape must not zero retained elements");
        p.reset(4, 4);
        assert!(p.re.iter().chain(&p.im).all(|&v| v == 0.0));
    }

    #[test]
    fn mat_ref_view_matches_tensor_indexing() {
        let mut rng = Xoshiro256::seed_from(5);
        let mut t = Tensor3::zeros(4, 3, 2);
        for z in &mut t.data {
            *z = C64::new(rng.normal(), rng.normal());
        }
        let p = PlanarTensor3::from_interleaved(&t);
        let v = p.as_mat_ref();
        assert_eq!((v.rows, v.cols), (4, 6));
        let im = t.as_mat_ref();
        for r in 0..4 {
            for c in 0..6 {
                assert_eq!(v.row_re(r)[c], im.row(r)[c].re);
                assert_eq!(v.row_im(r)[c], im.row(r)[c].im);
            }
        }
    }
}
