//! Dense complex tensors used by the native engines and at the PJRT
//! boundary.
//!
//! Shapes follow the paper's notation:
//! - left environment `E`: `(N, χ)` — one row per sample;
//! - MPS site tensor `Γ`: `(χ_l, χ_r, d)` — bond-in × bond-out × physical;
//! - unmeasured temporary: `(N, χ_r, d)`.
//!
//! Native compute stores interleaved `Complex<T>` by default; the planar
//! (split re/im) layout in [`planar`] is the SIMD hot-path alternative,
//! and the XLA boundary uses split re/im `f32` planes ([`SplitBuf`])
//! because the `xla` crate has no complex `Literal` constructors.

mod complex;
mod dense;
mod planar;
mod split;

pub use complex::{Complex, C32, C64};
pub use dense::{Mat, MatRef, Tensor3};
pub use planar::{PlanarMat, PlanarMatRef, PlanarTensor3};
pub use split::SplitBuf;
