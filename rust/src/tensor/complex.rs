//! Minimal complex scalar (num-complex is unavailable offline).

use crate::util::num::Float;
use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// Complex number over an arbitrary float.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
#[repr(C)]
pub struct Complex<T> {
    pub re: T,
    pub im: T,
}

pub type C32 = Complex<f32>;
pub type C64 = Complex<f64>;

impl<T: Float> Complex<T> {
    #[inline]
    pub fn new(re: T, im: T) -> Self {
        Complex { re, im }
    }

    #[inline]
    pub fn zero() -> Self {
        Complex {
            re: T::zero(),
            im: T::zero(),
        }
    }

    #[inline]
    pub fn one() -> Self {
        Complex {
            re: T::one(),
            im: T::zero(),
        }
    }

    #[inline]
    pub fn from_re(re: T) -> Self {
        Complex { re, im: T::zero() }
    }

    #[inline]
    pub fn i() -> Self {
        Complex {
            re: T::zero(),
            im: T::one(),
        }
    }

    #[inline]
    pub fn conj(self) -> Self {
        Complex {
            re: self.re,
            im: -self.im,
        }
    }

    /// Squared modulus |z|².
    #[inline]
    pub fn norm_sq(self) -> T {
        self.re * self.re + self.im * self.im
    }

    /// Modulus |z|.
    #[inline]
    pub fn abs(self) -> T {
        self.norm_sq().sqrt()
    }

    /// Scale by a real factor.
    #[inline]
    pub fn scale(self, s: T) -> Self {
        Complex {
            re: self.re * s,
            im: self.im * s,
        }
    }

    /// Complex exponential e^z.
    pub fn exp(self) -> Self {
        let r = self.re.exp();
        Complex {
            re: r * self.im.cos(),
            im: r * self.im.sin(),
        }
    }

    /// Multiplicative inverse.
    pub fn inv(self) -> Self {
        let d = self.norm_sq();
        Complex {
            re: self.re / d,
            im: -self.im / d,
        }
    }

    /// Fused multiply-add: self + a*b (kept explicit for the gemm kernels).
    #[inline]
    pub fn mul_add(self, a: Self, b: Self) -> Self {
        Complex {
            re: self.re + a.re * b.re - a.im * b.im,
            im: self.im + a.re * b.im + a.im * b.re,
        }
    }

    pub fn is_finite(self) -> bool {
        self.re.is_finite() && self.im.is_finite()
    }
}

impl C64 {
    pub fn to_c32(self) -> C32 {
        Complex {
            re: self.re as f32,
            im: self.im as f32,
        }
    }
}

impl C32 {
    pub fn to_c64(self) -> C64 {
        Complex {
            re: self.re as f64,
            im: self.im as f64,
        }
    }
}

impl<T: Float> Add for Complex<T> {
    type Output = Self;
    #[inline]
    fn add(self, o: Self) -> Self {
        Complex {
            re: self.re + o.re,
            im: self.im + o.im,
        }
    }
}

impl<T: Float> Sub for Complex<T> {
    type Output = Self;
    #[inline]
    fn sub(self, o: Self) -> Self {
        Complex {
            re: self.re - o.re,
            im: self.im - o.im,
        }
    }
}

impl<T: Float> Mul for Complex<T> {
    type Output = Self;
    #[inline]
    fn mul(self, o: Self) -> Self {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl<T: Float> Div for Complex<T> {
    type Output = Self;
    #[inline]
    fn div(self, o: Self) -> Self {
        self * o.inv()
    }
}

impl<T: Float> Neg for Complex<T> {
    type Output = Self;
    #[inline]
    fn neg(self) -> Self {
        Complex {
            re: -self.re,
            im: -self.im,
        }
    }
}

impl<T: Float + AddAssign> AddAssign for Complex<T> {
    #[inline]
    fn add_assign(&mut self, o: Self) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl<T: Float + SubAssign> SubAssign for Complex<T> {
    #[inline]
    fn sub_assign(&mut self, o: Self) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl<T: Float> MulAssign for Complex<T> {
    #[inline]
    fn mul_assign(&mut self, o: Self) {
        *self = *self * o;
    }
}

impl<T: Float + AddAssign> Sum for Complex<T> {
    fn sum<I: Iterator<Item = Self>>(iter: I) -> Self {
        let mut acc = Complex::zero();
        for x in iter {
            acc += x;
        }
        acc
    }
}

impl<T: Float + fmt::Display> fmt::Display for Complex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.im < T::zero() {
            write!(f, "{}-{}i", self.re, -self.im)
        } else {
            write!(f, "{}+{}i", self.re, self.im)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(re: f64, im: f64) -> C64 {
        Complex::new(re, im)
    }

    #[test]
    fn field_axioms_spotcheck() {
        let a = c(1.0, 2.0);
        let b = c(-0.5, 3.0);
        let z = c(0.0, 0.0);
        assert_eq!(a + b, b + a);
        assert_eq!(a * b, b * a);
        assert_eq!(a + z, a);
        assert_eq!(a * Complex::one(), a);
        let d = (a * b) * a.inv() - b;
        assert!(d.abs() < 1e-12);
    }

    #[test]
    fn conj_and_norm() {
        let a = c(3.0, -4.0);
        assert_eq!(a.abs(), 5.0);
        assert_eq!(a.norm_sq(), 25.0);
        assert_eq!(a.conj(), c(3.0, 4.0));
        let p = a * a.conj();
        assert!((p.re - 25.0).abs() < 1e-12 && p.im.abs() < 1e-12);
    }

    #[test]
    fn exp_euler() {
        let z = Complex::new(0.0, std::f64::consts::PI);
        let e = z.exp();
        assert!((e.re + 1.0).abs() < 1e-12 && e.im.abs() < 1e-12);
        // e^(a+b) = e^a e^b
        let a = c(0.3, -0.7);
        let b = c(-1.1, 0.4);
        let lhs = (a + b).exp();
        let rhs = a.exp() * b.exp();
        assert!((lhs - rhs).abs() < 1e-12);
    }

    #[test]
    fn mul_add_matches_expanded() {
        let acc = c(0.5, -0.25);
        let a = c(1.5, 2.0);
        let b = c(-3.0, 0.125);
        let got = acc.mul_add(a, b);
        let want = acc + a * b;
        assert!((got - want).abs() < 1e-14);
    }

    #[test]
    fn display_formats() {
        assert_eq!(c(1.0, 2.0).to_string(), "1+2i");
        assert_eq!(c(1.0, -2.0).to_string(), "1-2i");
    }
}
