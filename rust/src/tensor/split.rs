//! Split re/im f32 buffers — the representation crossing the PJRT boundary.
//!
//! The `xla` crate (0.1.6) exposes no complex `Literal` constructors, so the
//! L2 jax step functions take/return separate real and imaginary `f32`
//! planes and re-pack with `lax.complex` internally. `SplitBuf` is that
//! boundary type plus conversions to the interleaved native representation.

use crate::tensor::{Complex, Mat, Tensor3, C32, C64};
use crate::util::error::{Error, Result};
use crate::util::f16;

/// A logical complex array stored as two f32 planes plus a shape.
#[derive(Debug, Clone, PartialEq)]
pub struct SplitBuf {
    pub shape: Vec<usize>,
    pub re: Vec<f32>,
    pub im: Vec<f32>,
}

impl SplitBuf {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        SplitBuf {
            shape: shape.to_vec(),
            re: vec![0.0; n],
            im: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.re.len()
    }

    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }

    pub fn check(&self) -> Result<()> {
        let n: usize = self.shape.iter().product();
        if self.re.len() != n || self.im.len() != n {
            return Err(Error::shape(format!(
                "SplitBuf: shape {:?} ({n}) vs re {} im {}",
                self.shape,
                self.re.len(),
                self.im.len()
            )));
        }
        Ok(())
    }

    pub fn from_mat_c32(m: &Mat<f32>) -> Self {
        let mut re = Vec::with_capacity(m.data.len());
        let mut im = Vec::with_capacity(m.data.len());
        for z in &m.data {
            re.push(z.re);
            im.push(z.im);
        }
        SplitBuf {
            shape: vec![m.rows, m.cols],
            re,
            im,
        }
    }

    pub fn from_mat_c64(m: &Mat<f64>) -> Self {
        let mut re = Vec::with_capacity(m.data.len());
        let mut im = Vec::with_capacity(m.data.len());
        for z in &m.data {
            re.push(z.re as f32);
            im.push(z.im as f32);
        }
        SplitBuf {
            shape: vec![m.rows, m.cols],
            re,
            im,
        }
    }

    pub fn from_tensor3_c64(t: &Tensor3<f64>) -> Self {
        let mut re = Vec::with_capacity(t.data.len());
        let mut im = Vec::with_capacity(t.data.len());
        for z in &t.data {
            re.push(z.re as f32);
            im.push(z.im as f32);
        }
        SplitBuf {
            shape: vec![t.d0, t.d1, t.d2],
            re,
            im,
        }
    }

    pub fn to_mat_c32(&self) -> Result<Mat<f32>> {
        if self.shape.len() != 2 {
            return Err(Error::shape(format!(
                "to_mat_c32: shape {:?} is not rank-2",
                self.shape
            )));
        }
        let data: Vec<C32> = self
            .re
            .iter()
            .zip(&self.im)
            .map(|(&r, &i)| Complex::new(r, i))
            .collect();
        Mat::from_vec(self.shape[0], self.shape[1], data)
    }

    pub fn to_mat_c64(&self) -> Result<Mat<f64>> {
        if self.shape.len() != 2 {
            return Err(Error::shape(format!(
                "to_mat_c64: shape {:?} is not rank-2",
                self.shape
            )));
        }
        let data: Vec<C64> = self
            .re
            .iter()
            .zip(&self.im)
            .map(|(&r, &i)| Complex::new(r as f64, i as f64))
            .collect();
        Mat::from_vec(self.shape[0], self.shape[1], data)
    }

    pub fn to_tensor3_c64(&self) -> Result<Tensor3<f64>> {
        if self.shape.len() != 3 {
            return Err(Error::shape(format!(
                "to_tensor3_c64: shape {:?} is not rank-3",
                self.shape
            )));
        }
        let data: Vec<C64> = self
            .re
            .iter()
            .zip(&self.im)
            .map(|(&r, &i)| Complex::new(r as f64, i as f64))
            .collect();
        Tensor3::from_vec(self.shape[0], self.shape[1], self.shape[2], data)
    }

    /// Round both planes through binary16 — the paper's FP16 storage of the
    /// left environment ("doubling N₁ with the same memory cost").
    pub fn round_f16_in_place(&mut self) {
        for v in self.re.iter_mut().chain(self.im.iter_mut()) {
            *v = f16::round_f16(*v);
        }
    }

    /// Round both planes to TF32 input precision.
    pub fn round_tf32_in_place(&mut self) {
        for v in self.re.iter_mut().chain(self.im.iter_mut()) {
            *v = f16::round_tf32(*v);
        }
    }

    /// Zero-pad the *last* axis up to `new_last` (χ-bucket padding for the
    /// fixed-shape XLA artifacts). Padding with zeros is exact for both the
    /// contraction and the measurement (padded Λ entries are zero too).
    pub fn pad_last_axis(&self, new_last: usize) -> Result<SplitBuf> {
        let &last = self
            .shape
            .last()
            .ok_or_else(|| Error::shape("pad_last_axis on rank-0"))?;
        if new_last < last {
            return Err(Error::shape(format!(
                "pad_last_axis: {new_last} < current {last}"
            )));
        }
        let outer: usize = self.shape[..self.shape.len() - 1].iter().product();
        let mut out_shape = self.shape.clone();
        *out_shape.last_mut().unwrap() = new_last;
        let mut out = SplitBuf::zeros(&out_shape);
        for o in 0..outer {
            let src = o * last;
            let dst = o * new_last;
            out.re[dst..dst + last].copy_from_slice(&self.re[src..src + last]);
            out.im[dst..dst + last].copy_from_slice(&self.im[src..src + last]);
        }
        Ok(out)
    }

    /// Inverse of [`Self::pad_last_axis`]: keep only the first `new_last`
    /// entries of the last axis.
    pub fn crop_last_axis(&self, new_last: usize) -> Result<SplitBuf> {
        let &last = self
            .shape
            .last()
            .ok_or_else(|| Error::shape("crop_last_axis on rank-0"))?;
        if new_last > last {
            return Err(Error::shape(format!(
                "crop_last_axis: {new_last} > current {last}"
            )));
        }
        let outer: usize = self.shape[..self.shape.len() - 1].iter().product();
        let mut out_shape = self.shape.clone();
        *out_shape.last_mut().unwrap() = new_last;
        let mut out = SplitBuf::zeros(&out_shape);
        for o in 0..outer {
            let src = o * last;
            let dst = o * new_last;
            out.re[dst..dst + new_last].copy_from_slice(&self.re[src..src + new_last]);
            out.im[dst..dst + new_last].copy_from_slice(&self.im[src..src + new_last]);
        }
        Ok(out)
    }

    /// Max |z| (used by the global auto-scaling baseline).
    pub fn max_abs(&self) -> f32 {
        let mut m = 0.0f32;
        for (&r, &i) in self.re.iter().zip(&self.im) {
            let a = r * r + i * i;
            if a > m {
                m = a;
            }
        }
        m.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_roundtrip() {
        let mut m: Mat<f64> = Mat::zeros(2, 3);
        m[(0, 1)] = C64::new(1.5, -2.5);
        m[(1, 2)] = C64::new(-0.25, 4.0);
        let sb = SplitBuf::from_mat_c64(&m);
        assert_eq!(sb.shape, vec![2, 3]);
        let back = sb.to_mat_c64().unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn tensor3_roundtrip() {
        let mut t: Tensor3<f64> = Tensor3::zeros(2, 2, 3);
        *t.at_mut(1, 0, 2) = C64::new(7.0, -1.0);
        let sb = SplitBuf::from_tensor3_c64(&t);
        let back = sb.to_tensor3_c64().unwrap();
        assert_eq!(back, t);
        assert!(sb.to_mat_c64().is_err());
    }

    #[test]
    fn pad_crop_inverse() {
        let mut m: Mat<f64> = Mat::zeros(3, 4);
        for r in 0..3 {
            for c in 0..4 {
                m[(r, c)] = C64::new((r * 4 + c) as f64, -(r as f64));
            }
        }
        let sb = SplitBuf::from_mat_c64(&m);
        let padded = sb.pad_last_axis(7).unwrap();
        assert_eq!(padded.shape, vec![3, 7]);
        // Padding is zeros.
        assert_eq!(padded.re[4 + 3 - 3..7].iter().sum::<f32>(), 0.0);
        let back = padded.crop_last_axis(4).unwrap();
        assert_eq!(back, sb);
        assert!(sb.pad_last_axis(2).is_err());
        assert!(sb.crop_last_axis(9).is_err());
    }

    #[test]
    fn f16_rounding_applied() {
        let mut sb = SplitBuf::zeros(&[1, 2]);
        sb.re[0] = 1.0 + 1.0 / 4096.0; // not representable in f16
        sb.round_f16_in_place();
        assert_eq!(sb.re[0], 1.0);
        let mut sb2 = SplitBuf::zeros(&[1, 1]);
        sb2.im[0] = 1e-10;
        sb2.round_f16_in_place();
        assert_eq!(sb2.im[0], 0.0); // f16 underflow
    }

    #[test]
    fn check_validates_shape() {
        let mut sb = SplitBuf::zeros(&[2, 2]);
        assert!(sb.check().is_ok());
        sb.re.pop();
        assert!(sb.check().is_err());
    }
}
