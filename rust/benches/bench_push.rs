//! Throughput of the chunked store-push path: loopback upload of a
//! generated store into a `NetServer` (pipelined compression), a dedup
//! round trip, and a submit-by-key job against the pushed copy. Writes
//! `BENCH_push.json`.
//!
//! Run with `cargo bench --bench bench_push` from `rust/`.

use std::time::{Duration, Instant};

use fastmps::config::{ComputePrecision, NetConfig, Preset, ServiceConfig};
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::net::{Client, NetServer};
use fastmps::service::JobSpec;
use fastmps::util::bench;
use fastmps::util::json::Json;

const CHUNK_BYTES: usize = 64 << 10;

fn main() {
    bench::header("push", "loopback chunked store push (FMPN/TCP)");

    let root = std::env::temp_dir().join(format!("fastmps-bench-push-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let store_dir = root.join("store");
    let mut spec = Preset::BorealisM216H.scaled_spec(7);
    spec.m = 24;
    spec.chi_cap = 48;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.1;
    GammaStore::create(&store_dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap();

    let cfg = ServiceConfig {
        workers: 2,
        n2_micro: 128,
        target_batch: Some(1024),
        compute: ComputePrecision::F32,
        linger_ms: 2,
        ..Default::default()
    };
    let net = NetConfig {
        addr: "127.0.0.1:0".into(),
        push_dir: Some(root.join("pushed")),
        ..Default::default()
    };
    let server = NetServer::start(cfg, net.clone()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &net).unwrap();

    let t0 = Instant::now();
    let report = client.push_store(&store_dir, CHUNK_BYTES).unwrap();
    let push_secs = t0.elapsed().as_secs_f64();
    assert!(!report.dedup);

    let t1 = Instant::now();
    let again = client.push_store(&store_dir, CHUNK_BYTES).unwrap();
    let dedup_secs = t1.elapsed().as_secs_f64();
    assert!(again.dedup);

    let id = client.submit(&JobSpec::by_key(report.key, 2000)).unwrap();
    let res = client
        .wait(id, Duration::from_secs(300))
        .unwrap()
        .expect("job terminal within bench timeout");
    assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));

    let metrics = client.shutdown_server(Duration::from_secs(300)).unwrap();
    drop(client);
    let _ = server.shutdown();
    let _ = std::fs::remove_dir_all(&root);

    let wall = push_secs + dedup_secs;
    let mb = report.raw_bytes as f64 / 1e6;
    let push_mb_per_sec = if push_secs > 0.0 { mb / push_secs } else { 0.0 };
    let j = Json::obj(vec![
        ("bench", Json::Str("push-loopback".into())),
        ("measured", Json::Bool(true)),
        ("raw_bytes", Json::Num(report.raw_bytes as f64)),
        ("chunks", Json::Num(report.chunks as f64)),
        ("chunk_bytes", Json::Num(CHUNK_BYTES as f64)),
        ("wall_secs", Json::Num(wall)),
        ("push_secs", Json::Num(push_secs)),
        ("dedup_secs", Json::Num(dedup_secs)),
        ("push_mb_per_sec", Json::Num(push_mb_per_sec)),
        (
            "chunks_per_sec",
            Json::Num(if push_secs > 0.0 {
                report.chunks as f64 / push_secs
            } else {
                0.0
            }),
        ),
        ("service", metrics),
    ]);

    bench::row(&[
        ("raw_bytes", format!("{}", report.raw_bytes)),
        ("chunks", format!("{}", report.chunks)),
        ("push_secs", format!("{push_secs:.3}")),
        ("push_mb_per_sec", format!("{push_mb_per_sec:.2}")),
        ("dedup_secs", format!("{dedup_secs:.4}")),
    ]);
    bench::paper("no paper counterpart — §3.3-style compression+overlap applied to ingest");

    std::fs::write("../BENCH_push.json", j.pretty())
        .or_else(|_| std::fs::write("BENCH_push.json", j.pretty()))
        .unwrap();
    println!("  wrote BENCH_push.json");
}
