//! Fig. 9: validation — first/second-order correlation slopes vs the exact
//! marginals (panels a/c) and truncation error vs χ (panel b).

use std::sync::Arc;

use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::util::bench;

fn main() {
    bench::header("Fig. 9a/c", "correlation slopes, sampled vs exact");
    let mut spec = Preset::M8176.scaled_spec(9);
    spec.m = 48;
    spec.chi_cap = 32;
    spec.decay_k = 0.05;
    spec.displacement_sigma = 0.0;
    let dir = std::env::temp_dir().join(format!("fastmps-b9-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
    );

    let mut cfg = RunConfig::new(store.spec.clone());
    cfg.n_samples = 40_000;
    cfg.n1_macro = 5_000;
    cfg.n2_micro = 500;
    cfg.p1 = 4;
    cfg.engine = EngineKind::Native;
    cfg.compute = ComputePrecision::F32;
    cfg.scaling = ScalingMode::PerSample;
    let rep = data_parallel::run(&cfg, &store, &[]).unwrap();
    let mps = store.load_all().unwrap();
    let v = fastmps::validate::validate(&mps, &rep.sink).unwrap();
    bench::row(&[
        ("samples", format!("{}", cfg.n_samples)),
        ("first_order_slope", format!("{:.4}", v.first_order_slope)),
        ("second_order_slope", format!("{:.4}", v.second_order_slope)),
        ("max_site_err", format!("{:.4}", v.first_order_max_err)),
        ("pairs", format!("{}", v.pairs)),
    ]);
    bench::paper("slope 0.97 (1st order), 0.96 (2nd order), ideal 1 — Fig. 9 a/c");

    bench::header("Fig. 9b", "max truncation error vs bond dimension χ");
    let plan = Preset::M8176.full_spec(9).chi_plan();
    let mid = 8176 / 2;
    for chi in [2_000usize, 5_000, 10_000, 15_000, 20_000] {
        let err = plan.truncation_error(mid, chi);
        bench::row(&[
            ("chi", format!("{chi}")),
            ("max_truncation_error", format!("{err:.3e}")),
        ]);
    }
    bench::paper("decaying error with χ; ~0.675 even at χ=20000 mid-chain (Fig. 9b)");
    std::fs::remove_dir_all(&dir).unwrap();
}
