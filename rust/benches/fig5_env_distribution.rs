//! Fig. 5: distribution of left-environment magnitudes across samples at
//! increasing sites — the evidence for per-*sample* (not global) scaling.
//!
//! Prints, per probed site, the scatter summary (mean/max of per-sample
//! max |env|, and the max/min spread): the paper's panels a)–d) show the
//! spread exploding with the site index while each sample's internal range
//! stays ≤ 1e6.

use std::sync::Arc;

use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::util::bench;

fn main() {
    bench::header("Fig. 5", "left-env per-sample magnitude distribution vs site");
    // M8176 analog: probe sites at the same fractions as the paper's
    // {450, 2000, 5000, 7150}/8176.
    let mut spec = Preset::M8176.scaled_spec(5);
    spec.m = 128;
    spec.chi_cap = 48;
    spec.decay_k = 0.05;
    spec.branch_skew = 0.0;
    spec.displacement_sigma = 1.6; // the Fig. 5 spread source

    let dir = std::env::temp_dir().join(format!("fastmps-b5-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
    );
    let probes: Vec<usize> = [450usize, 2000, 5000, 7150]
        .iter()
        .map(|&s| s * spec.m / 8176)
        .collect();

    let mut cfg = RunConfig::new(store.spec.clone());
    cfg.n_samples = 1024;
    cfg.n1_macro = 1024;
    cfg.n2_micro = 256;
    cfg.engine = EngineKind::Native;
    cfg.compute = ComputePrecision::F64; // exact range tracking
    cfg.scaling = ScalingMode::Global; // the pre-fix view the paper plots
    let rep = data_parallel::run(&cfg, &store, &probes).unwrap();

    for (site, pts) in &rep.env_probes {
        let maxs: Vec<f64> = pts.iter().map(|(m, _)| *m).collect();
        let lo = maxs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = maxs.iter().cloned().fold(0.0f64, f64::max);
        let intra = pts
            .iter()
            .map(|(_, r)| *r)
            .filter(|r| r.is_finite())
            .fold(0.0f64, f64::max);
        bench::row(&[
            ("site", format!("{site}")),
            ("frac", format!("{:.2}", *site as f64 / spec.m as f64)),
            ("sample_max_range", format!("{:.2e}..{:.2e}", lo, hi)),
            (
                "inter_sample_decades",
                format!("{:.1}", (hi / lo.max(1e-300)).log10()),
            ),
            ("worst_intra_ratio", format!("{intra:.2e}")),
        ]);
    }
    bench::paper(
        "inter-sample maxima differ by hundreds of decades at late sites; \
         intra-sample range stays ~1e6 (Fig. 5 a–d)",
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
