//! Table 1: equivalent bond dimension, step ratio and comp ratio of the
//! dynamic-χ plans for the five evaluation datasets (d=4, χ=10⁴), plus the
//! ASP→profile model's predictions without the measured overrides.

use fastmps::config::ALL_PRESETS;
use fastmps::mps::entanglement::{plan_dynamic_chi, step_ratio_from_asp};
use fastmps::util::bench;

fn main() {
    bench::header("Table 1", "dynamic bond dimensions (d=4, χ_cap=10⁴)");
    let paper: &[(&str, f64, f64, f64)] = &[
        ("jiuzhang2", 4498.0, 0.0, 0.2023),
        ("jiuzhang3h", 7712.0, 0.4792, 0.5947),
        ("bm216h", 8321.0, 0.5879, 0.6923),
        ("bm288", 9132.0, 0.7951, 0.8339),
        ("m8176", 8923.0, 0.7429, 0.7961),
    ];
    println!("  (measured step-ratio overrides, as the paper's error filter produces)");
    for p in ALL_PRESETS {
        let spec = p.full_spec(1);
        let plan = spec.chi_plan();
        let row = paper.iter().find(|r| r.0 == p.name()).unwrap();
        bench::row(&[
            ("dataset", p.name().into()),
            (
                "equi_chi",
                format!("{:.0} (paper {:.0})", plan.equivalent_chi(), row.1),
            ),
            (
                "step_ratio",
                format!("{:.2}% (paper {:.2}%)", plan.step_ratio() * 100.0, row.2 * 100.0),
            ),
            (
                "comp_ratio",
                format!("{:.2}% (paper {:.2}%)", plan.comp_ratio() * 100.0, row.3 * 100.0),
            ),
            ("asp", format!("{}", spec.asp)),
        ]);
    }

    println!("\n  (pure ASP model, no overrides — the generic-dataset path)");
    for p in ALL_PRESETS {
        let spec = p.full_spec(1);
        let s = step_ratio_from_asp(spec.asp);
        let plan = plan_dynamic_chi(spec.m, 4, 10_000, s, 8);
        bench::row(&[
            ("dataset", p.name().into()),
            ("asp", format!("{}", spec.asp)),
            ("equi_chi", format!("{:.0}", plan.equivalent_chi())),
            ("comp_ratio", format!("{:.2}%", plan.comp_ratio() * 100.0)),
        ]);
    }
    bench::paper(
        "complexity reduction up to 80%; equi-χ increases with actual \
         squeezed photons (Table 1)",
    );
}
