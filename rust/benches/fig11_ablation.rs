//! Fig. 11: ablation — speedup of the fully-optimized FastMPS over versions
//! with one optimization removed: dynamic bond dimensions (§3.4.2), the
//! fast expm displacement (§3.4.1), and mixed precision (§3.3).
//!
//! Dynamic-χ and precision arms are measured end-to-end; the expm arm is
//! measured on the displacement kernel itself (general Padé `expm` vs the
//! analytic triangular factorization), exactly the component the paper
//! swaps. Mixed precision on this CPU testbed shows the f32-vs-f64 SIMD
//! factor (~2×); the paper's 16× comes from the A100 TF32:FP64 peak ratio,
//! which `table2_gpu_model` reports analytically.

use std::sync::Arc;

use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::linalg::{displacement_exact, displacement_fast_batch};
use fastmps::rng::Xoshiro256;
use fastmps::tensor::C64;
use fastmps::util::bench;

fn main() {
    bench::header("Fig. 11", "ablation: speedup of full FastMPS over -1 variants (bm288 analog)");
    let spec_dyn = Preset::BorealisM288.scaled_spec(17);
    let mut spec_fixed = spec_dyn.clone();
    spec_fixed.dynamic_chi = false;

    let mk = |spec: &fastmps::mps::gbs::GbsSpec, tag: &str| {
        let dir =
            std::env::temp_dir().join(format!("fastmps-b11-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        (
            Arc::new(
                GammaStore::create(&dir, spec, StorePrecision::F16, StoreCodec::Raw).unwrap(),
            ),
            dir,
        )
    };
    let (store_dyn, d1) = mk(&spec_dyn, "dyn");
    let (store_fixed, d2) = mk(&spec_fixed, "fixed");

    let run = |store: &Arc<GammaStore>, compute: ComputePrecision| {
        let mut cfg = RunConfig::new(store.spec.clone());
        cfg.n_samples = 2048;
        cfg.n1_macro = 1024;
        cfg.n2_micro = 256;
        cfg.p1 = 2;
        cfg.engine = EngineKind::Native;
        cfg.compute = compute;
        cfg.scaling = ScalingMode::PerSample;
        cfg.gemm_threads = 1;
        let (t, _) = bench::time(1, 3, || {
            data_parallel::run(&cfg, store, &[]).unwrap();
        });
        t
    };

    // Full pipeline (dynamic χ + f32 "mixed precision").
    let t_full = run(&store_dyn, ComputePrecision::F32);
    // − dynamic χ.
    let t_fixed = run(&store_fixed, ComputePrecision::F32);
    // − mixed precision (FP64 everywhere, as the baseline must).
    let t_fp64 = run(&store_dyn, ComputePrecision::F64);

    // − fast expm: component benchmark at production d and batch.
    let d = 4usize;
    let nb = 4096usize;
    let mut rng = Xoshiro256::seed_from(23);
    let mus: Vec<C64> = (0..nb)
        .map(|_| {
            let (re, im) = rng.complex_normal();
            C64::new(re * 0.3, im * 0.3)
        })
        .collect();
    let (t_fast, _) = bench::time(1, 3, || {
        std::hint::black_box(displacement_fast_batch(&mus, d).unwrap());
    });
    let (t_pade, _) = bench::time(1, 3, || {
        for &mu in mus.iter().take(256) {
            std::hint::black_box(displacement_exact(mu, d).unwrap());
        }
    });
    let t_pade_full = t_pade * (nb as f64 / 256.0);

    bench::row(&[
        ("full_pipeline_secs", format!("{t_full:.3}")),
        (
            "speedup_vs_no_dynamic_chi",
            format!("{:.2}x", t_fixed / t_full),
        ),
        (
            "speedup_vs_fp64",
            format!("{:.2}x (CPU SIMD; A100 TF32/FP64 peak = 16.4x)", t_fp64 / t_full),
        ),
        (
            "expm_speedup",
            format!("{:.1}x (batched analytic vs Padé)", t_pade_full / t_fast),
        ),
    ]);
    let comp = spec_dyn.chi_plan().comp_ratio();
    bench::row(&[(
        "dynamic_chi_comp_ratio",
        format!("{:.1}% of fixed-χ FLOPs (Table 1 predicts the arm above)", comp * 100.0),
    )]);
    bench::paper(
        "mixed precision dominates on GPU (~10x); expm opt gives a stable 2x \
         end-to-end (>10x on the component); dynamic χ tracks Table 1 (Fig. 11)",
    );
    std::fs::remove_dir_all(&d1).unwrap();
    std::fs::remove_dir_all(&d2).unwrap();
}
