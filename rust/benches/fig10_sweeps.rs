//! Fig. 10: time vs χ (a — quadratic), vs d (b — slow linear), and vs
//! micro batch N₂ (c — flat knee then linear), measured on the end-to-end
//! data-parallel walk with the native engine (wall time on this testbed;
//! the paper's absolute scale is A100).

use std::sync::Arc;

use fastmps::config::{ComputePrecision, EngineKind, RunConfig, ScalingMode};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::mps::gbs::GbsSpec;
use fastmps::util::bench;

fn make_store(tag: &str, chi: usize, d: usize) -> (Arc<GammaStore>, std::path::PathBuf) {
    let spec = GbsSpec {
        name: format!("sweep-{tag}"),
        m: 16,
        d,
        chi_cap: chi,
        asp: 6.0,
        decay_k: 0.02,
        displacement_sigma: 0.0,
            branch_skew: 0.0,
        seed: 10,
        dynamic_chi: false, // fixed χ isolates the χ² trend
        step_ratio_override: None,
    };
    let dir = std::env::temp_dir().join(format!("fastmps-b10-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
    );
    (store, dir)
}

fn run_once(store: &Arc<GammaStore>, n: u64, n2: usize) -> f64 {
    let mut cfg = RunConfig::new(store.spec.clone());
    cfg.n_samples = n;
    cfg.n1_macro = n as usize;
    cfg.n2_micro = n2.min(n as usize);
    cfg.engine = EngineKind::Native;
    cfg.compute = ComputePrecision::F32;
    cfg.scaling = ScalingMode::PerSample;
    cfg.gemm_threads = 2;
    let (mean, _) = bench::time(1, 3, || {
        data_parallel::run(&cfg, store, &[]).unwrap();
    });
    mean
}

fn main() {
    bench::header("Fig. 10a", "time vs bond dimension χ (d=3, N=4096)");
    let mut prev: Option<(usize, f64)> = None;
    for chi in [32usize, 64, 128, 192] {
        let (store, dir) = make_store(&format!("chi{chi}"), chi, 3);
        let t = run_once(&store, 4096, 512);
        let growth = prev
            .map(|(pc, pt)| {
                let expect = (chi as f64 / pc as f64).powi(2);
                format!("{:.2}x (χ² predicts {:.2}x)", t / pt, expect)
            })
            .unwrap_or_else(|| "-".into());
        bench::row(&[
            ("chi", format!("{chi}")),
            ("secs", format!("{t:.4}")),
            ("growth", growth),
        ]);
        prev = Some((chi, t));
        std::fs::remove_dir_all(&dir).unwrap();
    }
    bench::paper("time grows quadratically with χ (Fig. 10a)");

    bench::header("Fig. 10b", "time vs physical dimension d (χ=96, N=4096)");
    let mut base: Option<f64> = None;
    for d in [2usize, 3, 4, 5] {
        let (store, dir) = make_store(&format!("d{d}"), 96, d);
        let t = run_once(&store, 4096, 512);
        let rel = base.map(|b| format!("{:.2}x", t / b)).unwrap_or("-".into());
        bench::row(&[("d", format!("{d}")), ("secs", format!("{t:.4}")), ("vs_d2", rel)]);
        base = base.or(Some(t));
        std::fs::remove_dir_all(&dir).unwrap();
    }
    bench::paper("linear but slow growth with d — non-GEMM overheads dilute it (Fig. 10b)");

    bench::header("Fig. 10c", "time vs micro batch N₂ (χ=96, d=3, N=8192)");
    let (store, dir) = make_store("n2", 96, 3);
    let mut knee: Option<f64> = None;
    for n2 in [64usize, 128, 256, 512, 1024, 2048, 4096] {
        let t = run_once(&store, 8192, n2);
        bench::row(&[
            ("n2", format!("{n2}")),
            ("secs", format!("{t:.4}")),
            ("samples_per_sec", format!("{:.0}", 8192.0 * 16.0 / t)),
        ]);
        if knee.is_none() {
            knee = Some(t);
        }
    }
    bench::paper(
        "runtime flat below the knee (N≈5000 on A100), then linear; \
         pick the knee for arithmetic intensity (Fig. 10c)",
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
