//! Loopback throughput of the routing tier: submit→stream round trips
//! through a real `Router` in front of two real `NetServer`s, reporting
//! routed-job rate, affinity share, and spillover counts, written to
//! `BENCH_router.json`.
//!
//! Run with `cargo bench --bench bench_router` from `rust/`.

use std::time::{Duration, Instant};

use fastmps::config::{ComputePrecision, NetConfig, Preset, RouterConfig, ServiceConfig};
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::net::{Client, NetServer};
use fastmps::router::Router;
use fastmps::service::JobSpec;
use fastmps::util::bench;
use fastmps::util::json::Json;

const JOBS: usize = 24;
const SAMPLES_PER_JOB: u64 = 500;

fn main() {
    bench::header("router", "loopback routed submit→stream throughput (2 backends)");

    let root = std::env::temp_dir().join(format!("fastmps-bench-router-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let store_dir = root.join("store");
    let mut spec = Preset::Jiuzhang2.scaled_spec(7);
    spec.m = 10;
    spec.chi_cap = 16;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    GammaStore::create(&store_dir, &spec, StorePrecision::F16, StoreCodec::Lz).unwrap();

    let backend_cfg = || ServiceConfig {
        workers: 2,
        n2_micro: 128,
        target_batch: Some(1024),
        compute: ComputePrecision::F32,
        linger_ms: 2,
        ..Default::default()
    };
    let net = NetConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    let b1 = NetServer::start(backend_cfg(), net.clone()).unwrap();
    let b2 = NetServer::start(backend_cfg(), net.clone()).unwrap();
    let rcfg = RouterConfig {
        backends: vec![b1.local_addr().to_string(), b2.local_addr().to_string()],
        probe_interval_ms: 100,
        ..Default::default()
    };
    let router = Router::start(rcfg, net.clone()).unwrap();
    let addr = router.local_addr().to_string();
    let mut client = Client::connect(&addr, &net).unwrap();

    let t0 = Instant::now();
    let ids: Vec<u64> = (0..JOBS)
        .map(|k| {
            let mut s = JobSpec::new(&store_dir, SAMPLES_PER_JOB);
            s.sample_base = k as u64 * SAMPLES_PER_JOB;
            s.tag = format!("bench-router-{k}");
            client.submit(&s).unwrap()
        })
        .collect();
    let mut streamed = 0usize;
    for id in &ids {
        let res = client
            .wait(*id, Duration::from_secs(300))
            .unwrap()
            .expect("job terminal within bench budget");
        if res.sink.is_some() {
            streamed += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = router.metrics_json();
    drop(client);
    drop(router);
    drop(b1);
    drop(b2);
    let _ = std::fs::remove_dir_all(&root);

    let counter = |k: &str| {
        metrics
            .get("run")
            .and_then(|r| r.get("counters"))
            .and_then(|c| c.get(k))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    let total_samples = (JOBS as f64) * (SAMPLES_PER_JOB as f64);
    let submits = counter("router_submits");
    let spillovers = counter("router_spillovers");
    let j = Json::obj(vec![
        ("bench", Json::Str("router-loopback".into())),
        ("measured", Json::Bool(true)),
        ("jobs", Json::Num(JOBS as f64)),
        ("samples_per_job", Json::Num(SAMPLES_PER_JOB as f64)),
        ("backends", Json::Num(2.0)),
        ("payloads_streamed", Json::Num(streamed as f64)),
        ("wall_secs", Json::Num(wall)),
        (
            "jobs_per_sec",
            Json::Num(if wall > 0.0 { JOBS as f64 / wall } else { 0.0 }),
        ),
        (
            "samples_per_sec",
            Json::Num(if wall > 0.0 { total_samples / wall } else { 0.0 }),
        ),
        (
            "affinity_share",
            // One store ⇒ every job should land on its rendezvous pick;
            // spillovers only under induced Busy.
            Json::Num(if submits > 0.0 {
                (submits - spillovers) / submits
            } else {
                0.0
            }),
        ),
        ("spillovers", Json::Num(spillovers)),
        ("busy_rejects", Json::Num(counter("router_busy_rejects"))),
        ("router", metrics),
    ]);

    bench::row(&[
        ("jobs", format!("{JOBS}")),
        ("streamed", format!("{streamed}")),
        ("wall_secs", format!("{wall:.3}")),
        (
            "jobs_per_sec",
            format!("{:.1}", j.get("jobs_per_sec").unwrap().as_f64().unwrap()),
        ),
        (
            "affinity_share",
            format!("{:.3}", j.get("affinity_share").unwrap().as_f64().unwrap()),
        ),
        ("spillovers", format!("{spillovers:.0}")),
    ]);
    bench::paper("no paper counterpart — routing-tier KPIs for the ROADMAP north star");

    std::fs::write("../BENCH_router.json", j.pretty())
        .or_else(|_| {
            // Fall back to CWD when not run from `rust/`.
            std::fs::write("BENCH_router.json", j.pretty())
        })
        .unwrap();
    println!("  wrote BENCH_router.json");
}
