//! Fig. 13: strong scaling of tensor parallelism to 4 ranks on an NVLink3
//! fabric — double-site (AllReduce) vs single-site (ReduceScatter).
//! The paper measures 9.8 % efficiency decay for double-site and 39 % for
//! single-site at 4 GPUs, driven by B_a = 401 GB/s vs B_r ≈ 46 GB/s.

use std::sync::Arc;

use fastmps::comm::NetPreset;
use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::tensor_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::perfmodel;
use fastmps::util::bench;

fn main() {
    bench::header("Fig. 13", "TP strong scaling, single vs double site (NVLink3 fabric)");
    let mut spec = Preset::BorealisM288.scaled_spec(37);
    spec.m = 16;
    spec.chi_cap = 64;
    spec.decay_k = 0.02;
    spec.displacement_sigma = 0.0;
    let dir = std::env::temp_dir().join(format!("fastmps-b13-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
    );

    let run = |p2: usize, double: bool| {
        let mut cfg = RunConfig::new(store.spec.clone());
        cfg.n_samples = 4096;
        cfg.n1_macro = 4096;
        cfg.n2_micro = 4096;
        cfg.p2 = p2;
        cfg.double_site = double;
        cfg.engine = EngineKind::Native;
        cfg.compute = ComputePrecision::F64;
        cfg.scaling = ScalingMode::PerSample;
        cfg.net = NetPreset::NvLink3;
        // Model each rank as an A100-class device so the comm/compute
        // balance matches the paper's Fig. 13 regime.
        cfg.vdevice_flops = Some(1e12);
        tensor_parallel::run(&cfg, &store).unwrap()
    };

    for double in [true, false] {
        let name = if double { "double-site" } else { "single-site" };
        let base = run(1, double).vtime;
        for p2 in [1usize, 2, 4] {
            let rep = run(p2, double);
            let eff = base / (rep.vtime * p2 as f64) * 100.0;
            bench::row(&[
                ("scheme", name.into()),
                ("p2", format!("{p2}")),
                ("vtime", format!("{:.4}s", rep.vtime)),
                ("efficiency", format!("{eff:.1}%")),
                ("decay", format!("{:.1}%", 100.0 - eff)),
            ]);
        }
    }
    bench::paper("4 GPUs: 9.8% decay (double-site) vs 39% (single-site) — Fig. 13");

    bench::header("Eq. 7", "analytic TP overhead on the paper's shapes");
    let w = perfmodel::Workload {
        m: 288,
        chi: 10_000,
        d: 3,
        n_total: 400_000,
        n1: 20_000,
        scalar_bytes: 4,
    };
    for net in [NetPreset::NvLink3, NetPreset::Pcie4] {
        for double in [true, false] {
            let o = perfmodel::tp_overhead(&w, &perfmodel::A100_TF32, &net.model(), 4, double);
            bench::row(&[
                ("net", net.name().into()),
                (
                    "scheme",
                    if double { "double" } else { "single" }.into(),
                ),
                ("overhead", format!("{:.1}%", o * 100.0)),
                ("effective(<10%)", format!("{}", o < 0.10)),
            ]);
        }
    }
    bench::paper("PCIe TP is 'extremely inefficient'; NVLink3 favors double-site (§4.3)");
    std::fs::remove_dir_all(&dir).unwrap();
}
