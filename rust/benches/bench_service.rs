//! Service-path smoke benchmark: throughput, batch occupancy, and cache
//! hit rate of the resident batched sampling service, written to
//! `BENCH_service.json` (machine-readable) next to the human-readable rows.
//!
//! Run with `cargo bench --bench bench_service` from `rust/`.

use fastmps::service;
use fastmps::util::bench;

fn main() {
    bench::header("service", "resident batched sampling service smoke");
    let scratch = std::env::temp_dir().join(format!("fastmps-bench-service-{}", std::process::id()));
    std::fs::create_dir_all(&scratch).unwrap();
    let j = service::smoke_benchmark(&scratch, 4, 2000).unwrap();
    let _ = std::fs::remove_dir_all(&scratch);

    let f = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let svc = j.get("service").unwrap();
    bench::row(&[
        ("jobs", format!("{}", f("jobs"))),
        ("samples_per_job", format!("{}", f("samples_per_job"))),
        ("jobs_done", format!("{}", f("jobs_done"))),
        ("wall_secs", format!("{:.3}", f("wall_secs"))),
        (
            "throughput_samples_per_sec",
            format!("{:.0}", f("throughput_samples_per_sec")),
        ),
        (
            "batch_occupancy",
            format!(
                "{:.3}",
                svc.get("batch_occupancy").and_then(|v| v.as_f64()).unwrap_or(0.0)
            ),
        ),
        (
            "cache_hit_rate",
            format!(
                "{:.3}",
                svc.get("cache_hit_rate").and_then(|v| v.as_f64()).unwrap_or(0.0)
            ),
        ),
    ]);
    bench::paper("no paper counterpart — service KPIs for the ROADMAP north star");

    std::fs::write("../BENCH_service.json", j.pretty()).or_else(|_| {
        // Fall back to CWD when not run from `rust/`.
        std::fs::write("BENCH_service.json", j.pretty())
    })
    .unwrap();
    println!("  wrote BENCH_service.json");
}
