//! Fig. 6: sampling failure by underflow — average photon number per site
//! collapses to zero mid-chain with the baseline's global auto-scaling in
//! f32, and survives with FastMPS per-sample scaling.

use std::sync::Arc;

use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::util::bench;

fn main() {
    bench::header(
        "Fig. 6",
        "underflow collapse: avg photons vs site (global vs per-sample scaling, f32)",
    );
    let mut spec = Preset::M8176.scaled_spec(13);
    spec.m = 96;
    spec.chi_cap = 32;
    spec.decay_k = 0.02;
    spec.branch_skew = 0.0;
    // Displacement noise spreads per-sample magnitudes ~sqrt(site) decades.
    spec.displacement_sigma = 1.6;
    let dir = std::env::temp_dir().join(format!("fastmps-b6-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
    );

    let run = |scaling: ScalingMode| {
        let mut cfg = RunConfig::new(store.spec.clone());
        cfg.n_samples = 512;
        cfg.n1_macro = 512;
        cfg.n2_micro = 128;
        cfg.engine = EngineKind::Native;
        cfg.compute = ComputePrecision::F32;
        cfg.scaling = scaling;
        cfg.env_f16 = true; // S3.3.2 storage; compresses f32's range into 96 sites
        data_parallel::run(&cfg, &store, &[]).unwrap()
    };

    let global = run(ScalingMode::Global);
    let per_sample = run(ScalingMode::PerSample);
    let mg = global.sink.mean_photons();
    let mp = per_sample.sink.mean_photons();
    for site in (0..spec.m).step_by(6) {
        bench::row(&[
            ("site", format!("{site}")),
            ("global_f32", format!("{:.4}", mg[site])),
            ("per_sample_f32", format!("{:.4}", mp[site])),
        ]);
    }
    let collapse = mg.iter().position(|&m| m == 0.0);
    bench::row(&[
        ("global_dead_rows", format!("{}", global.dead_rows)),
        ("collapse_site", format!("{collapse:?}")),
        ("per_sample_dead_rows", format!("{}", per_sample.dead_rows)),
    ]);
    bench::paper(
        "auto-scaled run becomes a 0-tensor at site ~3000 of 8176; \
         FastMPS per-sample scaling holds TF32/f32 to the end (Fig. 6)",
    );
    assert!(
        global.dead_rows > 0 && per_sample.dead_rows == 0,
        "expected the paper's collapse/survival split"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
