//! Loopback throughput of the TCP transport: submit→stream round trips
//! through a real `NetServer` + `net::client::Client`, reporting job
//! round-trip rate, frames/s, and payload MB/s, written to
//! `BENCH_net.json`.
//!
//! Run with `cargo bench --bench bench_net` from `rust/`.

use std::time::{Duration, Instant};

use fastmps::config::{ComputePrecision, NetConfig, Preset, ServiceConfig};
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::net::{Client, NetServer};
use fastmps::service::JobSpec;
use fastmps::util::bench;
use fastmps::util::json::Json;

const JOBS: usize = 24;
const SAMPLES_PER_JOB: u64 = 500;

fn main() {
    bench::header("net", "loopback submit→stream throughput (FMPN/TCP)");

    let root = std::env::temp_dir().join(format!("fastmps-bench-net-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let store_dir = root.join("store");
    let mut spec = Preset::Jiuzhang2.scaled_spec(7);
    spec.m = 10;
    spec.chi_cap = 16;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    GammaStore::create(&store_dir, &spec, StorePrecision::F16, StoreCodec::Lz).unwrap();

    let cfg = ServiceConfig {
        workers: 2,
        n2_micro: 128,
        target_batch: Some(1024),
        compute: ComputePrecision::F32,
        linger_ms: 2,
        ..Default::default()
    };
    let net = NetConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    let server = NetServer::start(cfg, net.clone()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &net).unwrap();

    let t0 = Instant::now();
    let ids: Vec<_> = (0..JOBS)
        .map(|k| {
            let mut s = JobSpec::new(&store_dir, SAMPLES_PER_JOB);
            s.sample_base = k as u64 * SAMPLES_PER_JOB;
            s.tag = format!("bench-net-{k}");
            client.submit(&s).unwrap()
        })
        .collect();
    let mut streamed = 0usize;
    for id in ids {
        let res = client
            .wait(id, Duration::from_secs(300))
            .unwrap()
            .expect("job terminal within bench timeout");
        if res.sink.is_some() {
            streamed += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = client.shutdown_server(Duration::from_secs(300)).unwrap();
    drop(client);
    let _ = server.shutdown();
    let _ = std::fs::remove_dir_all(&root);

    let counter = |k: &str| {
        metrics
            .get("net")
            .and_then(|n| n.get("counters"))
            .and_then(|c| c.get(k))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    let frames = counter("net_frames_in") + counter("net_frames_out");
    let bytes = counter("net_bytes_in") + counter("net_bytes_out");
    let total_samples = (JOBS as f64) * (SAMPLES_PER_JOB as f64);
    let j = Json::obj(vec![
        ("bench", Json::Str("net-loopback".into())),
        ("jobs", Json::Num(JOBS as f64)),
        ("samples_per_job", Json::Num(SAMPLES_PER_JOB as f64)),
        ("payloads_streamed", Json::Num(streamed as f64)),
        ("wall_secs", Json::Num(wall)),
        (
            "jobs_per_sec",
            Json::Num(if wall > 0.0 { JOBS as f64 / wall } else { 0.0 }),
        ),
        (
            "samples_per_sec",
            Json::Num(if wall > 0.0 { total_samples / wall } else { 0.0 }),
        ),
        (
            "frames_per_sec",
            Json::Num(if wall > 0.0 { frames / wall } else { 0.0 }),
        ),
        (
            "wire_mb_per_sec",
            Json::Num(if wall > 0.0 { bytes / wall / 1e6 } else { 0.0 }),
        ),
        ("wire_bytes", Json::Num(bytes)),
        ("wire_frames", Json::Num(frames)),
        ("service", metrics),
    ]);

    bench::row(&[
        ("jobs", format!("{JOBS}")),
        ("streamed", format!("{streamed}")),
        ("wall_secs", format!("{wall:.3}")),
        (
            "jobs_per_sec",
            format!("{:.1}", j.get("jobs_per_sec").unwrap().as_f64().unwrap()),
        ),
        (
            "frames_per_sec",
            format!("{:.1}", j.get("frames_per_sec").unwrap().as_f64().unwrap()),
        ),
        (
            "wire_mb_per_sec",
            format!("{:.3}", j.get("wire_mb_per_sec").unwrap().as_f64().unwrap()),
        ),
    ]);
    bench::paper("no paper counterpart — transport KPIs for the ROADMAP north star");

    std::fs::write("../BENCH_net.json", j.pretty())
        .or_else(|_| {
            // Fall back to CWD when not run from `rust/`.
            std::fs::write("BENCH_net.json", j.pretty())
        })
        .unwrap();
    println!("  wrote BENCH_net.json");
}
