//! Hot-path step micro-benchmark: steps/sec, effective GFLOP/s, and
//! allocs/step of `NativeEngine::step_prepared` at several N×χ×d points,
//! written to `BENCH_step.json`.
//!
//! Exercises the tentpole optimizations directly: the prepared-site path
//! (no Γ clone/convert per step), the reusable step workspace
//! (allocs/step must read 0.000 after warm-up), the row-vs-bond GEMM
//! split (the small-N × large-χ points are where the bond split wins),
//! and the planar (split re/im) kernel layout vs the interleaved one —
//! each point runs both layouts and the summary reports the planar
//! speedup ratio (`planar_over_interleaved`).
//!
//! Run with `cargo bench --bench bench_step` from `rust/`.

use fastmps::config::{ComputePrecision, Layout, ScalingMode};
use fastmps::linalg::{matmul_flops, GemmSplit};
use fastmps::metrics::keys;
use fastmps::mps::Site;
use fastmps::rng::Xoshiro256;
use fastmps::sampler::native::NativeEngine;
use fastmps::sampler::PreparedSite;
use fastmps::tensor::{SplitBuf, Tensor3, C64};
use fastmps::util::bench;
use fastmps::util::json::Json;

fn square_site(chi: usize, d: usize, seed: u64) -> Site {
    let mut rng = Xoshiro256::seed_from(seed);
    let mut gamma = Tensor3::zeros(chi, chi, d);
    for z in &mut gamma.data {
        *z = C64::new(rng.normal() * 0.3, rng.normal() * 0.3);
    }
    Site {
        lambda: vec![1.0; chi],
        gamma,
    }
}

fn filled_env(n: usize, chi: usize, seed: u64) -> SplitBuf {
    let mut rng = Xoshiro256::seed_from(seed);
    let mut env = SplitBuf::zeros(&[n, chi]);
    for v in env.re.iter_mut().chain(env.im.iter_mut()) {
        *v = rng.normal() as f32;
    }
    env
}

struct Point {
    n: usize,
    chi: usize,
    d: usize,
    threads: usize,
    split: GemmSplit,
    layout: Layout,
}

fn run_point(p: &Point, reps: usize) -> Json {
    let site = square_site(p.chi, p.d, 42);
    let mut eng = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, p.threads);
    eng.split = p.split;
    eng.layout = p.layout;
    let prep = PreparedSite::prepare(&site, eng.prep_key());
    let mut env = filled_env(p.n, p.chi, 7);
    let th: Vec<f32> = (0..p.n).map(|i| ((i % 97) as f32 + 0.5) / 97.0).collect();
    let mus: Vec<(f64, f64)> = (0..p.n).map(|i| (0.01 * (i % 13) as f64, 0.02)).collect();
    let mut samples = Vec::new();
    // Explicit warm-up OUTSIDE the allocs-per-step baseline: the first
    // steps necessarily grow the empty workspace; the KPI measures the
    // steady state after them.
    for _ in 0..3 {
        eng.step_prepared(&mut env, &prep, &th, Some(&mus), &mut samples)
            .unwrap();
    }
    let grows0 = eng.metrics.get(keys::STEP_WS_GROWS);
    let steps0 = eng.metrics.get(keys::STEPS);
    let (mean, std) = bench::time(0, reps, || {
        eng.step_prepared(&mut env, &prep, &th, Some(&mus), &mut samples)
            .unwrap();
    });
    // One step = contraction + displacement + measurement (engine FLOP
    // accounting convention).
    let flops_per_step = matmul_flops(p.n, p.chi, p.chi * p.d)
        + 8 * (p.n * p.chi * p.d * p.d) as u64
        + 8 * (p.n * p.chi * p.d) as u64;
    let steps_per_sec = if mean > 0.0 { 1.0 / mean } else { 0.0 };
    let gflops = if mean > 0.0 {
        flops_per_step as f64 / mean / 1e9
    } else {
        0.0
    };
    // Steady state must read 0.000 (the counting-allocator test in
    // `sampler::native` asserts the hard zero-allocation form).
    let grows = eng.metrics.get(keys::STEP_WS_GROWS) - grows0;
    let steps = (eng.metrics.get(keys::STEPS) - steps0).max(1);
    let steady_allocs = grows as f64 / steps as f64;
    bench::row(&[
        ("n", format!("{}", p.n)),
        ("chi", format!("{}", p.chi)),
        ("d", format!("{}", p.d)),
        ("threads", format!("{}", p.threads)),
        ("split", p.split.as_str().into()),
        ("layout", p.layout.as_str().into()),
        ("steps_per_sec", format!("{steps_per_sec:.1}")),
        ("gflop_per_sec", format!("{gflops:.2}")),
        ("allocs_per_step", format!("{steady_allocs:.3}")),
        ("std_pct", format!("{:.1}", 100.0 * std / mean.max(1e-12))),
    ]);
    Json::obj(vec![
        ("n", Json::Num(p.n as f64)),
        ("chi", Json::Num(p.chi as f64)),
        ("d", Json::Num(p.d as f64)),
        ("threads", Json::Num(p.threads as f64)),
        ("split", Json::Str(p.split.as_str().into())),
        ("layout", Json::Str(p.layout.as_str().into())),
        ("steps_per_sec", Json::Num(steps_per_sec)),
        ("gflop_per_sec", Json::Num(gflops)),
        ("allocs_per_step", Json::Num(steady_allocs)),
    ])
}

fn main() {
    bench::header("step", "allocation-free prepared-site step hot path");
    let shapes = [
        // Large N: the classic data-parallel regime (row split).
        (256, 96, 3, 1, GemmSplit::Auto),
        (256, 96, 3, 4, GemmSplit::Auto),
        // Small N × wide bond: where the bond (column) split earns its keep.
        (8, 256, 4, 4, GemmSplit::Rows),
        (8, 256, 4, 4, GemmSplit::Cols),
        // Single-sample latency point.
        (1, 256, 4, 4, GemmSplit::Auto),
    ];
    // Every shape runs under BOTH layouts so the planar-vs-interleaved
    // ratio compares like against like (same shape, threads, split).
    let points: Vec<Point> = shapes
        .iter()
        .flat_map(|&(n, chi, d, threads, split)| {
            [Layout::Interleaved, Layout::Planar].map(|layout| Point {
                n,
                chi,
                d,
                threads,
                split,
                layout,
            })
        })
        .collect();
    let t0 = std::time::Instant::now();
    let results: Vec<Json> = points.iter().map(|p| run_point(p, 30)).collect();
    let wall = t0.elapsed().as_secs_f64();

    let best = results
        .iter()
        .filter_map(|j| j.get("steps_per_sec").and_then(|v| v.as_f64()))
        .fold(0.0f64, f64::max);
    let worst_allocs = results
        .iter()
        .filter_map(|j| j.get("allocs_per_step").and_then(|v| v.as_f64()))
        .fold(0.0f64, f64::max);
    let layout_gflops = |layout: &str| -> f64 {
        results
            .iter()
            .filter(|j| {
                j.get("layout").and_then(|v| v.as_str()) == Some(layout)
            })
            .filter_map(|j| j.get("gflop_per_sec").and_then(|v| v.as_f64()))
            .fold(0.0f64, f64::max)
    };
    let planar_gflops = layout_gflops("planar");
    let interleaved_gflops = layout_gflops("interleaved");
    let planar_over_interleaved = if interleaved_gflops > 0.0 {
        planar_gflops / interleaved_gflops
    } else {
        0.0
    };
    bench::paper(
        "§3: per-site step cost bounds sampling; resident tensors + bond-axis parallelism",
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("step-hotpath".into())),
        ("measured", Json::Bool(true)),
        ("wall_secs", Json::Num(wall)),
        ("steps_per_sec", Json::Num(best)),
        ("allocs_per_step_worst", Json::Num(worst_allocs)),
        ("planar_gflops", Json::Num(planar_gflops)),
        ("planar_over_interleaved", Json::Num(planar_over_interleaved)),
        ("points", Json::Arr(results)),
    ]);
    std::fs::write("../BENCH_step.json", out.pretty())
        .or_else(|_| std::fs::write("BENCH_step.json", out.pretty()))
        .unwrap();
    println!("  wrote BENCH_step.json");
}
