//! Fig. 12: weak and strong scaling of data parallelism, on the simulated
//! Tianhe-3 and Sunway fabrics (virtual time; the paper reports ≥95 %
//! efficiency on both machines) plus measured wall time on local threads.

use std::sync::Arc;

use fastmps::comm::NetPreset;
use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::util::bench;

fn main() {
    let mut spec = Preset::M8176.scaled_spec(29);
    spec.m = 32;
    spec.chi_cap = 32;
    spec.decay_k = 0.02;
    spec.displacement_sigma = 0.0;
    let dir = std::env::temp_dir().join(format!("fastmps-b12-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        GammaStore::create(&dir, &spec, StorePrecision::F16, StoreCodec::Raw).unwrap(),
    );

    let run = |p1: usize, n: u64, net: NetPreset| {
        let mut cfg = RunConfig::new(store.spec.clone());
        cfg.n_samples = n;
        cfg.n1_macro = 256;
        cfg.n2_micro = 128;
        cfg.p1 = p1;
        cfg.engine = EngineKind::Native;
        cfg.compute = ComputePrecision::F32;
        cfg.scaling = ScalingMode::PerSample;
        cfg.net = net;
        cfg.disk_bw = Some(5e9);
        // One modelled 50-GFLOP/s device per rank: the virtual clock is
        // then independent of testbed thread oversubscription.
        cfg.vdevice_flops = Some(50e9);
        data_parallel::run(&cfg, &store, &[]).unwrap()
    };

    for net in [NetPreset::Tianhe3, NetPreset::Sunway] {
        bench::header(
            &format!("Fig. 12 ({})", net.name()),
            "DP weak scaling: 1024 samples/worker (virtual time)",
        );
        let base = run(1, 1024, net).vtime;
        for p in [1usize, 2, 4, 8, 16] {
            let rep = run(p, 1024 * p as u64, net);
            bench::row(&[
                ("p", format!("{p}")),
                ("vtime", format!("{:.4}s", rep.vtime)),
                ("efficiency", format!("{:.1}%", base / rep.vtime * 100.0)),
            ]);
        }
        bench::header(
            &format!("Fig. 12 ({})", net.name()),
            "DP strong scaling: 8192 samples total (virtual time)",
        );
        let t1 = run(1, 8192, net).vtime;
        for p in [1usize, 2, 4, 8, 16] {
            let rep = run(p, 8192, net);
            bench::row(&[
                ("p", format!("{p}")),
                ("vtime", format!("{:.4}s", rep.vtime)),
                (
                    "efficiency",
                    format!("{:.1}%", t1 / (rep.vtime * p as f64) * 100.0),
                ),
            ]);
        }
    }

    bench::header("Fig. 12 (measured)", "strong scaling on local threads (wall time)");
    let w1 = run(1, 8192, NetPreset::Ideal).wall;
    for p in [1usize, 2, 4] {
        let rep = run(p, 8192, NetPreset::Ideal);
        bench::row(&[
            ("p", format!("{p}")),
            ("wall", format!("{:.3}s", rep.wall)),
            (
                "efficiency",
                format!("{:.1}%", w1 / (rep.wall * p as f64) * 100.0),
            ),
        ]);
    }
    bench::paper(">95% efficiency for weak AND strong scaling on Tianhe-3 (375 cores) and Sunway (32,500 cores) — Fig. 12 a–d");
    std::fs::remove_dir_all(&dir).unwrap();
}
