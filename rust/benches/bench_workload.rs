//! Workload comparison micro-benchmark: full-chain sampling walks at
//! d = 2 (qubit) vs the GBS physical dimension, through the same
//! `WorkloadSpec` path the service uses — generation, per-site threshold
//! draws, and the prepared-site step. Written to `BENCH_workload.json`.
//!
//! The point is to quantify what the workload abstraction buys: the
//! qubit chain does d²/d² less contraction work per site, and nothing in
//! the engine special-cases either workload.
//!
//! Run with `cargo bench --bench bench_workload` from `rust/`.

use fastmps::config::{ComputePrecision, Preset, ScalingMode};
use fastmps::mps::qubit::QubitSpec;
use fastmps::mps::workload::WorkloadSpec;
use fastmps::sampler::native::NativeEngine;
use fastmps::sampler::{boundary_env, PreparedSite};
use fastmps::util::bench;
use fastmps::util::json::Json;

const M: usize = 24;
const CHI: usize = 64;
const N: usize = 128;

fn run_workload(spec: &WorkloadSpec, reps: usize) -> Json {
    let mps = spec.generate().unwrap();
    let mut eng = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
    let preps: Vec<PreparedSite> = mps
        .sites
        .iter()
        .map(|s| PreparedSite::prepare(s, eng.prep_key()))
        .collect();
    let mut samples = Vec::new();
    let (mean, std) = bench::time(2, reps, || {
        // One full walk: threshold draws are part of the measurement
        // rule, so they stay inside the timed region.
        let mut env = boundary_env(N);
        for (i, prep) in preps.iter().enumerate() {
            let th = spec.thresholds(i, 0, N);
            eng.step_prepared(&mut env, prep, &th, None, &mut samples)
                .unwrap();
        }
    });
    let steps_per_sec = if mean > 0.0 {
        spec.m() as f64 / mean
    } else {
        0.0
    };
    let samples_per_sec = steps_per_sec * N as f64;
    bench::row(&[
        ("workload", spec.tag().to_string()),
        ("d", format!("{}", spec.d())),
        ("m", format!("{}", spec.m())),
        ("chi", format!("{CHI}")),
        ("n", format!("{N}")),
        ("steps_per_sec", format!("{steps_per_sec:.1}")),
        ("samples_per_sec", format!("{samples_per_sec:.0}")),
        ("std_pct", format!("{:.1}", 100.0 * std / mean.max(1e-12))),
    ]);
    Json::obj(vec![
        ("workload", Json::Str(spec.tag().into())),
        ("d", Json::Num(spec.d() as f64)),
        ("m", Json::Num(spec.m() as f64)),
        ("chi", Json::Num(CHI as f64)),
        ("n", Json::Num(N as f64)),
        ("steps_per_sec", Json::Num(steps_per_sec)),
        ("samples_per_sec", Json::Num(samples_per_sec)),
    ])
}

fn main() {
    bench::header(
        "workload",
        "full-chain walk at d=2 (qubit) vs the GBS physical dimension",
    );
    let mut gbs = Preset::Jiuzhang2.scaled_spec(42);
    gbs.m = M;
    gbs.chi_cap = CHI;
    gbs.decay_k = 0.0;
    gbs.displacement_sigma = 0.0;
    let gbs_d = gbs.d;
    let specs: [WorkloadSpec; 2] = [
        gbs.into(),
        QubitSpec::new("bench-qubit", M, CHI, 42).into(),
    ];

    let t0 = std::time::Instant::now();
    let results: Vec<Json> = specs.iter().map(|s| run_workload(s, 20)).collect();
    let wall = t0.elapsed().as_secs_f64();

    let rate = |i: usize| {
        results[i]
            .get("steps_per_sec")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    let (gbs_rate, qubit_rate) = (rate(0), rate(1));
    let speedup = if gbs_rate > 0.0 { qubit_rate / gbs_rate } else { 0.0 };
    bench::paper(&format!(
        "workload trait: same engine, d={gbs_d}→2 shrinks per-site work; qubit/gbs step ratio {speedup:.2}"
    ));

    let out = Json::obj(vec![
        ("bench", Json::Str("workload-dimension".into())),
        ("measured", Json::Bool(true)),
        ("wall_secs", Json::Num(wall)),
        ("gbs_steps_per_sec", Json::Num(gbs_rate)),
        ("qubit_steps_per_sec", Json::Num(qubit_rate)),
        ("qubit_over_gbs", Json::Num(speedup)),
        ("points", Json::Arr(results)),
    ]);
    std::fs::write("../BENCH_workload.json", out.pretty())
        .or_else(|_| std::fs::write("BENCH_workload.json", out.pretty()))
        .unwrap();
    println!("  wrote BENCH_workload.json");
}
