//! Table 2: GPU wall-clock comparison at paper scale (10M samples, χ=10⁴,
//! d=4) — FastMPS on 1/8 A100s vs the [19] baseline on 144–288 GPUs.
//!
//! This testbed has no GPUs, so the table is regenerated through the
//! calibrated analytic models (Eqs. 1/2 with A100 device constants; the
//! baseline runs FP64 + complex-double transfers, FastMPS runs TF32 + FP16
//! storage), anchored by the measured CPU head-to-head in
//! `table3_cpu_comparison`.

use fastmps::comm::NetPreset;
use fastmps::config::{Preset, ALL_PRESETS};
use fastmps::perfmodel::{
    time_data_parallel, time_model_parallel, Workload, A100_FP64, A100_TF32,
};
use fastmps::util::bench;

fn main() {
    bench::header(
        "Table 2",
        "paper-scale GPU minutes (modelled; 10M samples, χ=10⁴, d=4)",
    );
    let paper: &[(&str, f64, usize, f64, f64)] = &[
        // (dataset, baseline_min, baseline_gpus, fastmps1_min, fastmps8_min)
        ("jiuzhang2", 62.0, 144, 304.58, 38.57),
        ("jiuzhang3h", 62.0, 144, 693.75, 95.29),
        ("bm216h", 62.0, 216, 1111.62, 152.01),
        ("bm288", 62.0, 288, 1813.75, 247.43),
    ];
    let net = NetPreset::InfinibandHdr.model();
    for preset in ALL_PRESETS {
        if preset == Preset::M8176 {
            continue; // not in the paper's Table 2
        }
        let spec = preset.full_spec(1);
        let row = paper.iter().find(|r| r.0 == preset.name()).unwrap();
        // Dynamic-χ comp ratio shrinks the effective work exactly as the
        // paper's per-dataset runtimes differ under equal (M, χ, N).
        let comp = spec.chi_plan().comp_ratio();
        let w_fast = Workload {
            m: spec.m,
            chi: spec.chi_cap as u64,
            d: 4,
            n_total: 10_000_000,
            n1: 100_000,
            scalar_bytes: 2,
        };
        let w_base = Workload {
            scalar_bytes: 8,
            ..w_fast
        };
        let t_base = time_model_parallel(&w_base, &A100_FP64, &net) / 60.0;
        let t_fast1 = time_data_parallel(&w_fast, &A100_TF32, &net, 1) * comp / 60.0;
        let t_fast8 = time_data_parallel(&w_fast, &A100_TF32, &net, 8) * comp / 60.0;
        bench::row(&[
            ("dataset", preset.name().into()),
            (
                "baseline",
                format!("{t_base:.0}min/{}GPU (paper {:.0}min/{}GPU)", spec.m, row.1, row.2),
            ),
            ("fastmps_1gpu", format!("{t_fast1:.0}min (paper {:.0})", row.3)),
            ("fastmps_8gpu", format!("{t_fast8:.0}min (paper {:.0})", row.4)),
            (
                "8gpu_vs_baseline",
                format!("{:.2}x wall at {:.0}x fewer GPUs", row.1 / t_fast8.max(1e-9), spec.m as f64 / 8.0),
            ),
        ]);
    }
    bench::paper(
        "Jiuzhang2: 38.57 min on 8 GPUs vs 62 min on 144 GPUs; \
         per-GPU efficiency gain ≈ 18x (Table 2)",
    );
}
