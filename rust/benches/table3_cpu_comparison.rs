//! Table 3: measured CPU head-to-head at equal resources — FastMPS
//! data-parallel vs the [19] model-parallel baseline, single-threaded
//! compute, scaled-down shapes (the paper: χ=5000, 50K samples, one Xeon
//! core, 10.06×/8.09× speedups).
//!
//! The baseline arm runs exactly the baseline's configuration: FP64
//! compute, complex-double streaming, global auto-scaling, per-site
//! process pipeline. The FastMPS arm runs f32 + per-sample scaling + FP16
//! storage + dynamic χ through the data-parallel coordinator.

use std::sync::Arc;

use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::{data_parallel, model_parallel};
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::util::bench;

fn main() {
    bench::header(
        "Table 3",
        "measured CPU comparison (scaled shapes, single-threaded GEMM)",
    );
    let paper: &[(&str, f64)] = &[("jiuzhang2", 10.06), ("bm288", 8.09)];
    for (name, paper_speedup) in paper {
        let preset = Preset::parse(name).unwrap();
        let mut spec = preset.scaled_spec(41);
        spec.m = spec.m.min(48);
        spec.displacement_sigma = 0.0;
        spec.decay_k = 0.05;

        // FastMPS store: FP16 blobs + dynamic χ.
        let dir_fast =
            std::env::temp_dir().join(format!("fastmps-b3f-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir_fast);
        let store_fast = Arc::new(
            GammaStore::create(&dir_fast, &spec, StorePrecision::F16, StoreCodec::Raw).unwrap(),
        );
        // Baseline store: FP64 blobs + fixed χ (what [19] streams).
        let mut spec_base = spec.clone();
        spec_base.dynamic_chi = false;
        let dir_base =
            std::env::temp_dir().join(format!("fastmps-b3b-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir_base);
        let store_base = Arc::new(
            GammaStore::create(&dir_base, &spec_base, StorePrecision::F64, StoreCodec::Raw)
                .unwrap(),
        );

        let samples = 2048u64;
        let mut fast_cfg = RunConfig::new(store_fast.spec.clone());
        fast_cfg.n_samples = samples;
        fast_cfg.n1_macro = 512;
        fast_cfg.n2_micro = 256;
        fast_cfg.engine = EngineKind::Native;
        fast_cfg.compute = ComputePrecision::F32;
        fast_cfg.scaling = ScalingMode::PerSample;
        fast_cfg.store_precision = StorePrecision::F16;
        // Equal single-core resources: compare summed per-rank CPU time
        // (the MP baseline runs M pipeline ranks on this multicore box,
        // which a 1-core budget would serialize).
        let rep_fast = data_parallel::run(&fast_cfg, &store_fast, &[]).unwrap();
        let t_fast = rep_fast.metrics.phase("compute")
            + rep_fast.metrics.phase("measure")
            + rep_fast.metrics.phase("displace");

        let mut base_cfg = RunConfig::new(store_base.spec.clone());
        base_cfg.n_samples = samples;
        base_cfg.n1_macro = 512;
        base_cfg.n2_micro = 256;
        base_cfg.engine = EngineKind::Native;
        base_cfg.compute = ComputePrecision::F64;
        base_cfg.scaling = ScalingMode::Global;
        base_cfg.store_precision = StorePrecision::F64;
        let rep_base = model_parallel::run(&base_cfg, &store_base).unwrap();
        // CPU time only: pipe_recv is blocked *wait*, not work — a single
        // core executing the pipeline sequentially never waits.
        let t_base = rep_base.metrics.phase("compute") + rep_base.metrics.phase("measure");

        bench::row(&[
            ("dataset", (*name).into()),
            ("baseline_mp_fp64", format!("{t_base:.3}s")),
            ("fastmps_dp", format!("{t_fast:.3}s")),
            (
                "speedup",
                format!("{:.2}x (paper {paper_speedup:.2}x)", t_base / t_fast),
            ),
        ]);
        std::fs::remove_dir_all(&dir_fast).unwrap();
        std::fs::remove_dir_all(&dir_base).unwrap();
    }
    bench::paper(
        "Jiuzhang2-P65-1: 17.72h → 1.76h (10.06x); B-M288: 36.44h → 4.504h \
         (8.09x) on one Xeon core (Table 3). CPU speedup here composes \
         f32 SIMD, dynamic χ, pipeline-vs-DP structure and FP16 I/O; the \
         paper's exact factor also includes their vectorized kernels.",
    );
}
