//! End-to-end service integration: `serve` loop × file transport ×
//! coordinator engines × store cache, all through the public crate API —
//! the `serve` → `submit` → results round trip of the service subsystem.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use fastmps::config::{ComputePrecision, Preset, RunConfig, ServiceConfig};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::service::api::{self, ServeOptions};
use fastmps::service::{JobSpec, JobStatus, Service};
use fastmps::util::json::Json;

fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fastmps-itsvc-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn make_store(root: &Path) -> (Arc<GammaStore>, PathBuf) {
    let dir = root.join("store");
    let mut spec = Preset::Jiuzhang2.scaled_spec(33);
    spec.m = 6;
    spec.chi_cap = 10;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    let store =
        Arc::new(GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap());
    (store, dir)
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        n2_micro: 32,
        target_batch: Some(256),
        compute: ComputePrecision::F64,
        linger_ms: 2,
        poll_ms: 5,
        ..Default::default()
    }
}

#[test]
fn serve_submit_results_round_trip_with_shared_cache() {
    let root = scratch("roundtrip");
    let (store, store_dir) = make_store(&root);
    let jobs_dir = root.join("jobs");

    // Server in a background thread, drain mode: exits once all ingested
    // work is finished.
    let server = {
        let cfg = service_cfg();
        let opts = ServeOptions {
            jobs_dir: jobs_dir.clone(),
            poll_ms: 5,
            drain: true,
            max_secs: Some(120.0),
        };
        std::thread::spawn(move || api::serve(cfg, &opts))
    };

    // Two jobs against the SAME store, disjoint sample streams.
    let spec_a = JobSpec::new(&store_dir, 96);
    let mut spec_b = JobSpec::new(&store_dir, 96);
    spec_b.sample_base = 96;
    let stem_a = api::submit_file(&jobs_dir, &spec_a).unwrap();
    let stem_b = api::submit_file(&jobs_dir, &spec_b).unwrap();

    let res_a = api::wait_result(&jobs_dir, &stem_a, Duration::from_secs(60)).unwrap();
    let res_b = api::wait_result(&jobs_dir, &stem_b, Duration::from_secs(60)).unwrap();
    for (res, n) in [(&res_a, 96.0), (&res_b, 96.0)] {
        assert_eq!(res.get("status").unwrap().as_str(), Some("done"));
        assert_eq!(res.get("done").unwrap().as_f64(), Some(n));
        assert_eq!(
            res.get("mean_photons").unwrap().as_arr().unwrap().len(),
            store.spec.m
        );
        assert!(res.get("latency_secs").unwrap().as_f64().unwrap() > 0.0);
    }

    let metrics = server.join().unwrap().unwrap();
    // Acceptance: the two jobs shared one cached GammaStore.
    let counters = metrics.get("run").unwrap().get("counters").unwrap();
    let hits = counters.get("cache_hits").unwrap().as_f64().unwrap();
    let misses = counters.get("cache_misses").unwrap().as_f64().unwrap();
    assert!(hits > 0.0, "cache hits {hits} (misses {misses})");
    assert_eq!(misses, 1.0, "exactly one physical store open");
    assert!(metrics.get("cache_hit_rate").unwrap().as_f64().unwrap() > 0.0);

    // The on-disk metrics file matches what serve returned.
    let on_disk = std::fs::read_to_string(jobs_dir.join("service_metrics.json")).unwrap();
    assert_eq!(Json::parse(&on_disk).unwrap(), metrics);

    // Status files reached terminal state too.
    let listed = api::list_jobs(&jobs_dir).unwrap();
    assert_eq!(listed.len(), 2);
    for (stem, j) in &listed {
        assert_eq!(j.get("status").unwrap().as_str(), Some("done"), "{stem}");
    }

    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn service_statistics_match_one_shot_coordinator() {
    // A job through the full service must produce exactly the histogram a
    // one-shot `data_parallel::run` produces for the same sample range.
    let root = scratch("oracle");
    let (store, store_dir) = make_store(&root);
    let svc = Service::start(service_cfg()).unwrap();
    let id = svc.submit(JobSpec::new(&store_dir, 160)).unwrap();
    assert_eq!(svc.wait(id, Duration::from_secs(60)), Some(JobStatus::Done));
    let sink = svc.queue().job_sink(id).unwrap();
    drop(svc);

    let mut rc = RunConfig::new(store.spec.clone());
    rc.n_samples = 160;
    rc.n1_macro = 160;
    rc.n2_micro = 32;
    rc.compute = ComputePrecision::F64;
    rc.store_precision = store.precision;
    let reference = data_parallel::run(&rc, &store, &[]).unwrap();
    assert_eq!(sink.hist, reference.sink.hist);
    assert_eq!(sink.pair_sums, reference.sink.pair_sums);

    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn mixed_store_traffic_is_batched_separately_but_served() {
    // Jobs against two different stores interleave; each gets its own
    // batches and both complete correctly.
    let root = scratch("mixed");
    let (_, dir_a) = make_store(&root);
    let dir_b = root.join("store-b");
    let mut spec = Preset::Jiuzhang3H.scaled_spec(44);
    spec.m = 5;
    spec.chi_cap = 8;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    GammaStore::create(&dir_b, &spec, StorePrecision::F16, StoreCodec::Lz).unwrap();

    let svc = Service::start(service_cfg()).unwrap();
    let ids: Vec<_> = (0..4)
        .map(|k| {
            let dir = if k % 2 == 0 { &dir_a } else { &dir_b };
            let mut s = JobSpec::new(dir, 40);
            s.sample_base = (k as u64 / 2) * 40;
            svc.submit(s).unwrap()
        })
        .collect();
    for id in ids {
        assert_eq!(
            svc.wait(id, Duration::from_secs(60)),
            Some(JobStatus::Done),
            "job {id}"
        );
    }
    assert_eq!(svc.cache().misses(), 2, "two distinct stores opened");
    drop(svc);
    std::fs::remove_dir_all(&root).unwrap();
}
