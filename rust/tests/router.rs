//! End-to-end routing-tier integration: two real loopback `NetServer`s
//! behind one `Router`, driven through the unchanged `net::client`.
//! Proves the ISSUE's acceptance behaviors: store affinity (same
//! manifest → same backend), `Busy` spillover to the next-ranked
//! backend, typed busy once every backend is saturated, graceful drain
//! with zero dropped in-flight jobs, and down-backend exclusion.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use fastmps::config::{ComputePrecision, NetConfig, Preset, RouterConfig, RunConfig, ServiceConfig};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::net::{Client, NetServer};
use fastmps::router::{rendezvous, HealthState, Router};
use fastmps::service::JobSpec;

fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fastmps-itroute-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn make_store(root: &Path) -> (Arc<GammaStore>, PathBuf) {
    let dir = root.join("store");
    let mut spec = Preset::Jiuzhang2.scaled_spec(77);
    spec.m = 6;
    spec.chi_cap = 10;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    let store =
        Arc::new(GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap());
    (store, dir)
}

fn backend_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        n2_micro: 32,
        target_batch: Some(256),
        compute: ComputePrecision::F64,
        linger_ms: 2,
        ..Default::default()
    }
}

fn loopback_net() -> NetConfig {
    NetConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    }
}

fn router_cfg(backends: Vec<String>) -> RouterConfig {
    RouterConfig {
        backends,
        probe_interval_ms: 50,
        backoff_base_ms: 1,
        backoff_cap_ms: 4,
        jitter_ms: 0,
        ..Default::default()
    }
}

/// `run.counters.<key>` of a metrics JSON.
fn counter(metrics: &fastmps::util::json::Json, key: &str) -> f64 {
    metrics
        .get("run")
        .and_then(|r| r.get("counters"))
        .and_then(|c| c.get(key))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0)
}

fn backend_submitted(server: &NetServer) -> f64 {
    counter(&server.service().metrics_json(), "jobs_submitted")
}

#[test]
fn same_manifest_jobs_share_a_backend_and_payloads_survive_forwarding() {
    let root = scratch("affinity");
    let (store, store_dir) = make_store(&root);
    let b1 = NetServer::start(backend_cfg(), loopback_net()).unwrap();
    let b2 = NetServer::start(backend_cfg(), loopback_net()).unwrap();
    let addrs = vec![b1.local_addr().to_string(), b2.local_addr().to_string()];
    let router = Router::start(router_cfg(addrs.clone()), loopback_net()).unwrap();
    let mut client = Client::connect(&router.local_addr().to_string(), &loopback_net()).unwrap();
    client.ping().unwrap();

    let a = client.submit(&JobSpec::new(&store_dir, 96)).unwrap();
    let mut spec_b = JobSpec::new(&store_dir, 96);
    spec_b.sample_base = 96;
    spec_b.tag = "routed-b".into();
    let b = client.submit(&spec_b).unwrap();
    assert_ne!(a, b, "router-global ids are distinct");

    let res_a = client.wait(a, Duration::from_secs(60)).unwrap().unwrap();
    let res_b = client.wait(b, Duration::from_secs(60)).unwrap().unwrap();
    for res in [&res_a, &res_b] {
        assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
        assert_eq!(res.result.get("done").unwrap().as_f64(), Some(96.0));
    }
    // Result ids are rewritten to the router-global ids.
    assert_eq!(res_a.result.get("id").unwrap().as_f64(), Some(a as f64));

    // Affinity: both jobs landed on the rendezvous-chosen backend, the
    // other stayed cold.
    let expected = rendezvous::rank(JobSpec::new(&store_dir, 1).store_key(), &addrs)[0];
    let (hot, cold) = if expected == 0 { (&b1, &b2) } else { (&b2, &b1) };
    assert_eq!(backend_submitted(hot), 2.0, "both jobs on the HRW choice");
    assert_eq!(backend_submitted(cold), 0.0, "no stray placement");

    // Payloads forwarded through the router are exact: the union of the
    // two jobs' sinks equals a direct coordinator run over [0, 192).
    let mut rc = RunConfig::new(store.spec.clone());
    rc.n_samples = 192;
    rc.n1_macro = 192;
    rc.n2_micro = 32;
    rc.compute = ComputePrecision::F64;
    rc.store_precision = store.precision;
    let reference = data_parallel::run(&rc, &store, &[]).unwrap();
    let mut combined = res_a.sink.clone().unwrap();
    combined.merge(res_b.sink.as_ref().unwrap());
    assert_eq!(combined.hist, reference.sink.hist);
    assert_eq!(combined.pair_sums, reference.sink.pair_sums);

    // status / list speak router-global ids.
    let view = client.status(a).unwrap();
    assert_eq!(view.get("id").unwrap().as_f64(), Some(a as f64));
    let listed = client.list().unwrap();
    let ids: Vec<f64> = listed
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| j.get("id").unwrap().as_f64().unwrap())
        .collect();
    assert_eq!(ids, vec![a as f64, b as f64]);

    // Router metrics: submits counted, no spillover, no rejects.
    let m = client.metrics().unwrap();
    assert_eq!(counter(&m, "router_submits"), 2.0);
    assert_eq!(counter(&m, "router_spillovers"), 0.0);
    assert_eq!(counter(&m, "router_busy_rejects"), 0.0);

    drop(client);
    drop(router);
    drop(b1);
    drop(b2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn busy_backend_spills_over_then_saturation_is_typed_busy() {
    let root = scratch("spill");
    let (_, store_dir) = make_store(&root);
    // One queue slot per backend; a long linger keeps an admitted job
    // holding that slot while the next submission arrives.
    let cfg = || ServiceConfig {
        max_queue: 1,
        linger_ms: 400,
        ..backend_cfg()
    };
    let b1 = NetServer::start(cfg(), loopback_net()).unwrap();
    let b2 = NetServer::start(cfg(), loopback_net()).unwrap();
    let addrs = vec![b1.local_addr().to_string(), b2.local_addr().to_string()];
    let mut rcfg = router_cfg(addrs.clone());
    rcfg.retry_budget = 4;
    let router = Router::start(rcfg, loopback_net()).unwrap();
    let mut client = Client::connect(&router.local_addr().to_string(), &loopback_net()).unwrap();

    // First job occupies the rendezvous-first backend; the second gets
    // its Busy and spills to the next-ranked one.
    let a = client.submit(&JobSpec::new(&store_dir, 64)).unwrap();
    let mut spec_b = JobSpec::new(&store_dir, 64);
    spec_b.sample_base = 64;
    let b = client.submit(&spec_b).unwrap();

    let expected = rendezvous::rank(JobSpec::new(&store_dir, 1).store_key(), &addrs)[0];
    let (first, second) = if expected == 0 { (&b1, &b2) } else { (&b2, &b1) };
    assert_eq!(backend_submitted(first), 1.0, "affinity pick took job a");
    assert_eq!(backend_submitted(second), 1.0, "busy spillover took job b");

    // Both slots held: a third submission exhausts the retry budget and
    // comes back as a typed busy (retryable), not a hard error.
    let mut spec_c = JobSpec::new(&store_dir, 64);
    spec_c.sample_base = 128;
    let err = client
        .submit(&spec_c)
        .expect_err("both backends saturated must reject");
    assert!(err.is_busy(), "typed busy, got: {err}");

    let m = client.metrics().unwrap();
    assert!(counter(&m, "router_spillovers") >= 1.0);
    assert!(counter(&m, "router_busy_rejects") >= 1.0);

    // Busy is transient: once the fleet drains, the same submit works.
    assert!(client.wait(a, Duration::from_secs(60)).unwrap().is_some());
    assert!(client.wait(b, Duration::from_secs(60)).unwrap().is_some());
    let c = client.submit(&spec_c).unwrap();
    assert!(client.wait(c, Duration::from_secs(60)).unwrap().is_some());

    drop(client);
    drop(router);
    drop(b1);
    drop(b2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn forced_spillover_replays_as_one_stitched_timeline() {
    use std::collections::BTreeSet;

    let root = scratch("tracespill");
    let (_, store_dir) = make_store(&root);
    // Same saturation setup as the spillover test above: one queue slot
    // per backend, long linger, so the traced job reliably gets a Busy
    // from its first-choice backend before landing on the runner-up.
    let cfg = || ServiceConfig {
        max_queue: 1,
        linger_ms: 400,
        ..backend_cfg()
    };
    let b1 = NetServer::start(cfg(), loopback_net()).unwrap();
    let b2 = NetServer::start(cfg(), loopback_net()).unwrap();
    let addrs = vec![b1.local_addr().to_string(), b2.local_addr().to_string()];
    let mut rcfg = router_cfg(addrs.clone());
    rcfg.retry_budget = 4;
    let router = Router::start(rcfg, loopback_net()).unwrap();
    let mut client = Client::connect(&router.local_addr().to_string(), &loopback_net()).unwrap();

    let a = client.submit(&JobSpec::new(&store_dir, 64)).unwrap();
    let mut spec_b = JobSpec::new(&store_dir, 64);
    spec_b.sample_base = 64;
    let (b, trace) = client.submit_traced(&spec_b).unwrap();
    assert_ne!(trace, 0);
    assert!(client.wait(b, Duration::from_secs(60)).unwrap().is_some());

    // Replay through the router by the global id alone: the router must
    // resolve the trace id itself and stitch its own placement events
    // with the winning backend's, rewriting backend-local job ids.
    let reply = client.trace_events(b, 0).unwrap();
    let hex = format!("{trace:016x}");
    assert_eq!(reply.get("trace").unwrap().as_str(), Some(hex.as_str()));
    assert_eq!(reply.get("job").unwrap().as_f64(), Some(b as f64));
    let events = reply.get("events").unwrap().as_arr().unwrap().to_vec();
    assert!(!events.is_empty());

    // Placement story, in full: an attempt on the rendezvous-first
    // backend, its busy verdict, the retry on the runner-up, and the
    // spillover marker — args carry 1-based backend indices.
    let expected = rendezvous::rank(JobSpec::new(&store_dir, 1).store_key(), &addrs)[0];
    let first = expected as f64 + 1.0;
    let second = (1 - expected) as f64 + 1.0;
    let router_events: Vec<(&str, f64)> = events
        .iter()
        .filter(|e| e.get("layer").unwrap().as_str() == Some("router"))
        .map(|e| {
            (
                e.get("name").unwrap().as_str().unwrap(),
                e.get("arg").and_then(|v| v.as_f64()).unwrap_or(0.0),
            )
        })
        .collect();
    assert!(
        router_events.contains(&("attempt", first)),
        "failed first-choice attempt missing from {router_events:?}"
    );
    assert!(router_events.contains(&("busy", first)), "{router_events:?}");
    assert!(router_events.contains(&("attempt", second)), "{router_events:?}");
    assert!(router_events.contains(&("spillover", second)), "{router_events:?}");
    assert!(router_events.iter().any(|(n, _)| *n == "place"));

    // The winning backend's execution spans are in the same timeline…
    let names: BTreeSet<&str> = events
        .iter()
        .map(|e| e.get("name").unwrap().as_str().unwrap())
        .collect();
    for want in ["queue_wait", "batch", "job_done", "encode"] {
        assert!(names.contains(want), "missing backend {want} in {names:?}");
    }
    // …keyed by the router-global id, never a backend-local one.
    for e in &events {
        if let Some(j) = e.get("job").and_then(|v| v.as_f64()) {
            assert_eq!(j, b as f64, "backend-local id leaked: {e:?}");
        }
    }

    // Merged order: non-decreasing timestamps, and the failed attempt
    // strictly precedes the winning backend's batch execution.
    let ts: Vec<f64> = events
        .iter()
        .map(|e| e.get("t_us").unwrap().as_f64().unwrap())
        .collect();
    assert!(ts.windows(2).all(|p| p[0] <= p[1]), "stitched events sorted");
    let idx = |name: &str| {
        events
            .iter()
            .position(|e| e.get("name").unwrap().as_str() == Some(name))
            .unwrap()
    };
    assert!(idx("busy") < idx("batch"), "rejection precedes execution");

    // Both renderings accept the stitched reply.
    let human = fastmps::trace::render_human(&reply);
    assert!(human.contains("spillover"), "{human}");
    let chrome = fastmps::trace::chrome_trace(&reply);
    let te = chrome.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(te.len(), events.len());
    assert!(te
        .iter()
        .all(|e| e.get("ts").unwrap().as_f64().unwrap() >= 0.0));

    assert!(client.wait(a, Duration::from_secs(60)).unwrap().is_some());
    drop(client);
    drop(router);
    drop(b1);
    drop(b2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn router_drain_finishes_in_flight_jobs_and_refuses_new_ones() {
    let root = scratch("drain");
    let (_, store_dir) = make_store(&root);
    // A long linger keeps the job in flight when the drain starts.
    let cfg = || ServiceConfig {
        linger_ms: 300,
        ..backend_cfg()
    };
    let b1 = NetServer::start(cfg(), loopback_net()).unwrap();
    let b2 = NetServer::start(cfg(), loopback_net()).unwrap();
    let addrs = vec![b1.local_addr().to_string(), b2.local_addr().to_string()];
    let router = Router::start(router_cfg(addrs), loopback_net()).unwrap();
    let addr = router.local_addr().to_string();
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();

    let id = client.submit(&JobSpec::new(&store_dir, 96)).unwrap();
    // Drain races the linger window: the reply must prove the routed job
    // ran to completion with nothing dropped.
    let metrics = client.shutdown_server(Duration::from_secs(120)).unwrap();
    assert_eq!(metrics.get("jobs_routed").unwrap().as_f64(), Some(1.0));
    assert_eq!(metrics.get("jobs_in_flight").unwrap().as_f64(), Some(0.0));
    assert_eq!(counter(&metrics, "router_dropped_jobs"), 0.0, "zero dropped");
    assert!(router.shutdown_requested());

    // The job really finished on its backend (not cancelled, not lost).
    let completed = counter(&b1.service().metrics_json(), "jobs_completed")
        + counter(&b2.service().metrics_json(), "jobs_completed");
    let failed = counter(&b1.service().metrics_json(), "jobs_failed")
        + counter(&b2.service().metrics_json(), "jobs_failed");
    assert_eq!(completed, 1.0);
    assert_eq!(failed, 0.0);

    // The shutdown reply closed the original connection; a fresh one can
    // still fetch the terminal result, but new work is refused while
    // draining (a deliberate error, not busy).
    let mut late = Client::connect(&addr, &loopback_net()).unwrap();
    let res = late.wait(id, Duration::from_secs(30)).unwrap().unwrap();
    assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
    let err = late
        .submit(&JobSpec::new(&store_dir, 8))
        .expect_err("post-drain submit must fail");
    assert!(!err.is_busy());
    assert!(err.to_string().contains("shutting down"), "{err}");

    drop(client);
    drop(late);
    drop(router);
    drop(b1);
    drop(b2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn qubit_store_pushed_and_sampled_through_router_matches_oracle() {
    use fastmps::mps::exact::exact_site_distributions;
    use fastmps::mps::qubit::QubitSpec;
    use fastmps::mps::workload::{Workload, WorkloadKind};

    // The workload-abstraction acceptance path: push a d=2 store through
    // the router, submit with an explicit qubit declaration, and check
    // the streamed sink against the exact-enumeration oracle. Nothing in
    // the router or service knows the workload beyond its tag.
    let root = scratch("qubit-e2e");
    let qspec = QubitSpec::new("routed-qubit", 6, 6, 23);
    let store_dir = root.join("qubit-store");
    // F64 storage: the pushed bytes reproduce `generate()` exactly, so
    // the transfer-matrix oracle over the generated chain is the truth.
    GammaStore::create(&store_dir, qspec.clone(), StorePrecision::F64, StoreCodec::Raw).unwrap();

    let backend_net = |tag: &str| NetConfig {
        push_dir: Some(root.join(format!("pushed-{tag}"))),
        ..loopback_net()
    };
    let b1 = NetServer::start(backend_cfg(), backend_net("b1")).unwrap();
    let b2 = NetServer::start(backend_cfg(), backend_net("b2")).unwrap();
    let addrs = vec![b1.local_addr().to_string(), b2.local_addr().to_string()];
    let router = Router::start(router_cfg(addrs), loopback_net()).unwrap();
    let mut client = Client::connect(&router.local_addr().to_string(), &loopback_net()).unwrap();

    let report = client.push_store(&store_dir, 2048).unwrap();
    assert!(!report.dedup);

    // Submit by content key with the qubit declaration; enough samples
    // for tight binomial error bars.
    let n = 4096usize;
    let mut spec = JobSpec::by_key(report.key, n);
    spec.workload = WorkloadKind::Qubit;
    spec.compute = Some(ComputePrecision::F64);
    let id = client.submit(&spec).unwrap();
    let res = client.wait(id, Duration::from_secs(120)).unwrap().unwrap();
    assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
    assert_eq!(res.result.get("workload").unwrap().as_str(), Some("qubit"));
    let sink = res.sink.expect("payload streamed back through the router");

    // Exact enumeration over the same chain the backend sampled.
    let mps = qspec.generate().unwrap();
    let exact = exact_site_distributions(&mps).unwrap();
    assert_eq!(sink.hist.len(), qspec.m);
    for (site, h) in sink.hist.iter().enumerate() {
        assert_eq!(h.len(), 2, "site {site}: binary outcome alphabet");
        assert_eq!(h[0] + h[1], n as u64);
        let p1 = h[1] as f64 / n as f64;
        // Binomial error at N=4096 is ≤ 0.5/√4096 ≈ 0.008; allow 5σ.
        assert!(
            (p1 - exact[site][1]).abs() < 0.04,
            "site {site}: sampled P(1) = {p1} vs exact {}",
            exact[site][1]
        );
    }

    // A wrong declaration against the same store is a typed failure, not
    // a silent GBS run: the dispatcher checks the manifest tag.
    let mut wrong = JobSpec::by_key(report.key, 8);
    wrong.workload = WorkloadKind::Gbs;
    wrong.sample_base = n as u64;
    let wid = client.submit(&wrong).unwrap();
    let wres = client.wait(wid, Duration::from_secs(60)).unwrap().unwrap();
    assert_eq!(wres.result.get("status").unwrap().as_str(), Some("failed"));
    let err = wres.result.get("error").unwrap().as_str().unwrap();
    assert!(err.contains("workload mismatch"), "typed refusal, got: {err}");

    // The listing carries the workload column through the router.
    let listed = client.list().unwrap();
    let tags: Vec<&str> = listed
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| j.get("workload").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(tags, vec!["qubit", "gbs"]);

    drop(client);
    drop(router);
    drop(b1);
    drop(b2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn dead_backend_goes_down_and_traffic_routes_around_it() {
    let root = scratch("down");
    let (_, store_dir) = make_store(&root);
    let live = NetServer::start(backend_cfg(), loopback_net()).unwrap();
    // A bound-then-dropped listener: connections are refused immediately.
    let dead_addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let mut rcfg = router_cfg(vec![live.local_addr().to_string(), dead_addr.clone()]);
    rcfg.probe_interval_ms = 30;
    rcfg.degraded_after = 1;
    rcfg.down_after = 2;
    let router = Router::start(rcfg, loopback_net()).unwrap();

    // The prober marks the dead backend Down within a few intervals.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let health = router.health();
        if health[1].1 == HealthState::Down {
            assert_eq!(health[0].1, HealthState::Alive);
            break;
        }
        assert!(std::time::Instant::now() < deadline, "never marked down");
        std::thread::sleep(Duration::from_millis(20));
    }

    // Every submit lands on the live backend, whatever its rendezvous
    // rank; the job completes end to end.
    let mut client = Client::connect(&router.local_addr().to_string(), &loopback_net()).unwrap();
    let id = client.submit(&JobSpec::new(&store_dir, 64)).unwrap();
    let res = client.wait(id, Duration::from_secs(60)).unwrap().unwrap();
    assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
    assert_eq!(backend_submitted(&live), 1.0);

    // The metrics expose the per-backend states.
    let m = client.metrics().unwrap();
    let states: Vec<String> = m
        .get("backends")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|b| b.get("state").unwrap().as_str().unwrap().to_string())
        .collect();
    assert_eq!(states, vec!["alive".to_string(), "down".to_string()]);
    assert!(counter(&m, "router_probe_failures") >= 2.0);

    drop(client);
    drop(router);
    drop(live);
    std::fs::remove_dir_all(&root).unwrap();
}
