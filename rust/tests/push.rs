//! End-to-end chunked store push (`docs/PROTOCOL.md` § Chunked store
//! push): a client uploads a multi-chunk store through the router to the
//! rendezvous-chosen backend — no shared data volume anywhere — then
//! submits a job by content key and checks the streamed sink against a
//! locally-sampled oracle. Also covers direct-to-server push, dedup,
//! restart recovery, and the staging quota.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use fastmps::config::{
    ComputePrecision, NetConfig, Preset, RouterConfig, RunConfig, ServiceConfig,
};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::net::{Client, NetServer};
use fastmps::router::Router;
use fastmps::service::JobSpec;

fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fastmps-itpush-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn make_store(root: &Path) -> (Arc<GammaStore>, PathBuf) {
    let dir = root.join("source-store");
    let mut spec = Preset::Jiuzhang2.scaled_spec(77);
    spec.m = 6;
    spec.chi_cap = 10;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    let store =
        Arc::new(GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap());
    (store, dir)
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        n2_micro: 32,
        target_batch: Some(256),
        compute: ComputePrecision::F64,
        linger_ms: 2,
        ..Default::default()
    }
}

fn loopback_net() -> NetConfig {
    NetConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    }
}

fn backend_net(root: &Path, tag: &str) -> NetConfig {
    NetConfig {
        push_dir: Some(root.join(format!("pushed-{tag}"))),
        ..loopback_net()
    }
}

#[test]
fn push_through_router_then_submit_by_key_matches_oracle() {
    let root = scratch("e2e");
    let (store, store_dir) = make_store(&root);

    // Two backends, each with its own private push dir — the source
    // store's path is never given to either, so jobs can only succeed if
    // the chunked push actually delivered the bytes.
    let b1 = NetServer::start(service_cfg(), backend_net(&root, "b1")).unwrap();
    let b2 = NetServer::start(service_cfg(), backend_net(&root, "b2")).unwrap();
    let rcfg = RouterConfig {
        backends: vec![b1.local_addr().to_string(), b2.local_addr().to_string()],
        probe_interval_ms: 50,
        ..Default::default()
    };
    let router = Router::start(rcfg, loopback_net()).unwrap();
    let addr = router.local_addr().to_string();

    let mut client = Client::connect(&addr, &loopback_net()).unwrap();
    // Small chunks force a genuinely multi-chunk transfer.
    let report = client.push_store(&store_dir, 2048).unwrap();
    assert!(!report.dedup);
    assert!(report.chunks > 1, "multi-chunk push ({} chunks)", report.chunks);

    // Exactly one backend holds the store: the rendezvous choice.
    let on1 = b1.service().cache().knows(report.key);
    let on2 = b2.service().cache().knows(report.key);
    assert!(on1 ^ on2, "store on exactly one backend (b1={on1} b2={on2})");

    // Submit by content key — the spec carries no path at all — and the
    // router's affinity lands it on the backend that has the store.
    let mut spec = JobSpec::by_key(report.key, 96);
    spec.compute = Some(ComputePrecision::F64);
    let id = client.submit(&spec).unwrap();
    let res = client.wait(id, Duration::from_secs(60)).unwrap().unwrap();
    assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
    let sink = res.sink.expect("payload streamed back through the router");

    // Oracle: the same sample range computed locally from the source.
    let mut rc = RunConfig::new(store.spec.clone());
    rc.n_samples = 96;
    rc.n1_macro = 96;
    rc.n2_micro = 32;
    rc.compute = ComputePrecision::F64;
    rc.store_precision = store.precision;
    let reference = data_parallel::run(&rc, &store, &[]).unwrap();
    assert_eq!(sink.hist, reference.sink.hist);
    assert_eq!(sink.counts, reference.sink.counts);
    assert_eq!(sink.pair_sums, reference.sink.pair_sums);

    // A second push of the same store is deduplicated by manifest hash:
    // nothing is re-transferred.
    let mut c2 = Client::connect(&addr, &loopback_net()).unwrap();
    let again = c2.push_store(&store_dir, 2048).unwrap();
    assert!(again.dedup, "second push must dedup");
    assert_eq!(again.key, report.key);
    assert_eq!(again.raw_bytes, 0, "nothing re-transferred");

    // A key nobody holds is refused synchronously through the router —
    // a terminal error (not busy: retrying cannot conjure the store).
    let err = c2
        .submit(&JobSpec::by_key(report.key ^ 1, 8))
        .expect_err("unknown key must be refused at submit");
    assert!(!err.is_busy(), "terminal, not backpressure: {err}");
    assert!(err.to_string().contains("unknown store key"), "{err}");

    // Router metrics split uploads from dedups, mirroring the server.
    let m = client.metrics().unwrap();
    let run = m.get("run").unwrap().get("counters").unwrap();
    assert_eq!(
        run.get("router_pushes").unwrap().as_f64(),
        Some(1.0),
        "one completed upload"
    );
    assert_eq!(
        run.get("router_push_dedups").unwrap().as_f64(),
        Some(1.0),
        "one dedup'd push_begin"
    );

    drop(client);
    drop(c2);
    drop(router);
    drop(b1);
    drop(b2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn direct_push_dedup_and_restart_recovery() {
    let root = scratch("direct");
    let (_, store_dir) = make_store(&root);
    let net = backend_net(&root, "solo");
    let push_dir = net.push_dir.clone().unwrap();

    let key = {
        let server = NetServer::start(service_cfg(), net.clone()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr, &net).unwrap();
        let report = client.push_store(&store_dir, 4096).unwrap();
        assert!(!report.dedup);
        // Same connection, same store: dedup without re-upload.
        let again = client.push_store(&store_dir, 4096).unwrap();
        assert!(again.dedup);
        // The job runs from the pushed copy.
        let id = client.submit(&JobSpec::by_key(report.key, 32)).unwrap();
        let res = client.wait(id, Duration::from_secs(60)).unwrap().unwrap();
        assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
        let m = client.metrics().unwrap();
        let netc = m.get("net").unwrap().get("counters").unwrap();
        assert_eq!(netc.get("net_pushes").unwrap().as_f64(), Some(1.0));
        assert_eq!(netc.get("net_push_dedups").unwrap().as_f64(), Some(1.0));
        drop(client);
        drop(server);
        report.key
    };

    // A fresh server over the same push dir re-registers installed
    // stores at startup: the key resolves with no new push.
    let server = NetServer::start(service_cfg(), net.clone()).unwrap();
    assert!(
        server.service().cache().knows(key),
        "restart recovery re-registers installed stores"
    );
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &net).unwrap();
    let report = client.push_store(&store_dir, 4096).unwrap();
    assert!(report.dedup, "installed store dedups across restarts");
    let id = client.submit(&JobSpec::by_key(key, 16)).unwrap();
    let res = client.wait(id, Duration::from_secs(60)).unwrap().unwrap();
    assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
    assert!(push_dir.join(format!("store-{key:016x}")).exists());

    // Admission checks keys synchronously: an unknown key never becomes
    // an accepted-then-failed job.
    let err = client
        .submit(&JobSpec::by_key(key ^ 0xff, 8))
        .expect_err("unknown key refused at admission");
    assert!(err.to_string().contains("unknown store key"), "{err}");

    drop(client);
    drop(server);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn push_disabled_and_quota_are_clean_rejections() {
    let root = scratch("reject");
    let (_, store_dir) = make_store(&root);

    // No push dir: typed error, connection stays usable.
    let server = NetServer::start(service_cfg(), loopback_net()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();
    let err = client.push_store(&store_dir, 4096).expect_err("disabled");
    assert!(err.to_string().contains("disabled"), "{err}");
    client.ping().unwrap(); // no desync: nothing was streamed
    drop(client);
    drop(server);

    // Staging quota: an announced size over the cap is refused up front.
    let net = NetConfig {
        push_chunk_bytes: 1024,
        push_staging_bytes: 2048, // far below the store's stream size
        ..backend_net(&root, "quota")
    };
    let server = NetServer::start(service_cfg(), net.clone()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &net).unwrap();
    let err = client.push_store(&store_dir, 1024).expect_err("quota");
    assert!(err.to_string().contains("staging quota"), "{err}");
    client.ping().unwrap();
    let pushed = net.push_dir.as_ref().unwrap();
    assert!(
        !pushed.exists() || std::fs::read_dir(pushed).unwrap().next().is_none(),
        "nothing staged or installed"
    );
    drop(client);
    drop(server);
    std::fs::remove_dir_all(&root).unwrap();
}
