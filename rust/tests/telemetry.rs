//! End-to-end telemetry plane: a live Prometheus exposition scraped over
//! real HTTP from a loopback `NetServer` and from a `Router` fleet, the
//! `telemetry` wire op feeding ring history to `fastmps top`, and the
//! exposition validator run against what the exporters actually serve.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastmps::cli::run_cli;
use fastmps::config::{ComputePrecision, NetConfig, Preset, RouterConfig, ServiceConfig};
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::net::{Client, NetServer};
use fastmps::router::Router;
use fastmps::service::JobSpec;
use fastmps::telemetry::prom::validate_exposition;
use fastmps::telemetry::top::{render, TopView};

fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fastmps-ittel-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn make_store(root: &Path) -> (Arc<GammaStore>, PathBuf) {
    let dir = root.join("store");
    let mut spec = Preset::Jiuzhang2.scaled_spec(55);
    spec.m = 6;
    spec.chi_cap = 10;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    let store =
        Arc::new(GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap());
    (store, dir)
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        n2_micro: 32,
        target_batch: Some(256),
        compute: ComputePrecision::F64,
        linger_ms: 2,
        ..Default::default()
    }
}

fn loopback_net() -> NetConfig {
    NetConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    }
}

/// A loopback net config with a fast telemetry interval and an ephemeral
/// exposition port.
fn telemetry_net() -> NetConfig {
    NetConfig {
        addr: "127.0.0.1:0".into(),
        telemetry_interval_ms: 25,
        metrics_listen: Some("127.0.0.1:0".into()),
        ..Default::default()
    }
}

/// One raw HTTP/1.0 GET; returns (status+headers, body).
fn scrape(addr: SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(s, "GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let (head, body) = buf
        .split_once("\r\n\r\n")
        .expect("response has a header/body split");
    (head.to_string(), body.to_string())
}

#[test]
fn server_exposition_scrapes_live_and_validates() {
    let root = scratch("prom");
    let (_store, store_dir) = make_store(&root);
    let server = NetServer::start(service_cfg(), telemetry_net()).unwrap();
    let maddr = server.metrics_addr().expect("exporter bound");
    let addr = server.local_addr().to_string();

    let mut client = Client::connect(&addr, &loopback_net()).unwrap();
    let id = client.submit(&JobSpec::new(&store_dir, 64)).unwrap();
    client.wait(id, Duration::from_secs(60)).unwrap().unwrap();

    let (head, body) = scrape(maddr, "/metrics");
    assert!(head.starts_with("HTTP/1.0 200"), "{head}");
    assert!(head.contains("text/plain; version=0.0.4"), "{head}");
    assert!(body.contains("fastmps_jobs_completed_total 1"), "{body}");
    assert!(
        body.contains("fastmps_queue_wait_seconds_bucket"),
        "log2 histogram must render as cumulative le buckets:\n{body}"
    );
    assert!(body.contains("fastmps_queue_wait_seconds_count"));
    assert!(body.contains("fastmps_queue_depth"));
    validate_exposition(&body).unwrap_or_else(|e| panic!("invalid exposition: {e}\n{body}"));

    let (head, _) = scrape(maddr, "/nope");
    assert!(head.starts_with("HTTP/1.0 404"), "{head}");

    // The telemetry op serves ring history; after a couple of intervals
    // there is more than the startup sample, and the latest one reflects
    // the completed job.
    std::thread::sleep(Duration::from_millis(80));
    let reply = client.telemetry().unwrap();
    assert!(reply.get("interval_ms").unwrap().as_f64() == Some(25.0));
    let samples = reply.get("samples").unwrap().as_arr().unwrap();
    assert!(samples.len() >= 2, "ring should have accumulated samples");
    let last = samples.last().unwrap();
    assert_eq!(last.get("jobs_completed").unwrap().as_f64(), Some(1.0));
    assert!(last.get("unix_ms").unwrap().as_f64().unwrap() > 0.0);

    // A `top` frame built from the same reply shows the headline fields.
    let frame = render(&TopView::parse(&addr, &reply));
    assert!(frame.contains("queue depth"), "{frame}");
    assert!(frame.contains("jobs/s"), "{frame}");
    assert!(frame.contains("p99"), "{frame}");

    drop(client);
    drop(server);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn router_exposition_labels_backends_and_top_shows_fleet() {
    let root = scratch("fleet");
    let (_store, store_dir) = make_store(&root);
    let backend = NetServer::start(service_cfg(), loopback_net()).unwrap();
    let rcfg = RouterConfig {
        backends: vec![backend.local_addr().to_string()],
        probe_interval_ms: 25,
        ..Default::default()
    };
    let router = Router::start(rcfg, telemetry_net()).unwrap();
    let maddr = router.metrics_addr().expect("router exporter bound");
    let raddr = router.local_addr().to_string();

    let mut client = Client::connect(&raddr, &loopback_net()).unwrap();
    let id = client.submit(&JobSpec::new(&store_dir, 64)).unwrap();
    client.wait(id, Duration::from_secs(60)).unwrap().unwrap();

    // Poll until the fleet poller has scraped the backend *after* the job
    // completed, so the labeled series carry the final counters.
    let deadline = Instant::now() + Duration::from_secs(10);
    let body = loop {
        let (head, body) = scrape(maddr, "/metrics");
        assert!(head.starts_with("HTTP/1.0 200"), "{head}");
        if body.contains("fastmps_jobs_completed_total{backend=\"0\"} 1") {
            break body;
        }
        assert!(
            Instant::now() < deadline,
            "fleet poller never served the backend's completed-job counter:\n{body}"
        );
        std::thread::sleep(Duration::from_millis(25));
    };
    validate_exposition(&body).unwrap_or_else(|e| panic!("invalid exposition: {e}\n{body}"));
    // Router's own series, unlabeled.
    assert!(body.contains("fastmps_router_submits_total 1"), "{body}");
    assert!(body.contains("fastmps_router_health_degraded_total"));
    assert!(body.contains("fastmps_router_health_down_total"));
    // Fleet series: health gauge, info series, and the scraped backend
    // document re-rendered under its index label.
    assert!(body.contains("fastmps_router_backend_state{backend=\"0\"} 0"), "{body}");
    assert!(body.contains("fastmps_router_backend_info{"));
    assert!(body.contains("fastmps_jobs_completed_total{backend=\"0\"} 1"), "{body}");

    // Router telemetry op: own ring plus one per-backend sample ring.
    let reply = client.telemetry().unwrap();
    assert!(!reply.get("samples").unwrap().as_arr().unwrap().is_empty());
    let backends = reply.get("backends").unwrap().as_arr().unwrap();
    assert_eq!(backends.len(), 1);
    assert_eq!(backends[0].get("state").unwrap().as_str(), Some("alive"));
    assert!(!backends[0].get("samples").unwrap().as_arr().unwrap().is_empty());

    // Per-backend rows make it into the dashboard frame.
    let frame = render(&TopView::parse(&raddr, &reply));
    assert!(frame.contains("backends"), "{frame}");
    assert!(frame.contains("alive"), "{frame}");

    // And the CLI path renders one frame end-to-end.
    let argv: Vec<String> = format!("top --connect {raddr} --once")
        .split_whitespace()
        .map(String::from)
        .collect();
    run_cli(&argv).unwrap();

    drop(client);
    drop(router);
    drop(backend);
    let _ = std::fs::remove_dir_all(&root);
}
