//! Fleet tensor-parallel integration: a 2-member TP group over real
//! loopback sockets, formed by the router from pushed shards, must
//! produce a sample sink byte-identical to the same job run serially on
//! one backend (`docs/TENSOR_PARALLEL.md` § Bit identity). Also proves
//! the failure contract: unregistered or incomplete groups and down
//! members refuse typed — TP jobs never spill over and never hang.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use fastmps::config::{ComputePrecision, NetConfig, Preset, RouterConfig, ServiceConfig};
use fastmps::io::{manifest_hash_at, GammaStore, StoreCodec, StorePrecision};
use fastmps::net::frame;
use fastmps::net::{Client, NetServer};
use fastmps::router::{rendezvous, HealthState, Router};
use fastmps::service::{JobSpec, TpGroup};
use fastmps::util::json::Json;

fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fastmps-ittp-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn make_store(root: &Path) -> (Arc<GammaStore>, PathBuf) {
    let dir = root.join("store");
    let mut spec = Preset::Jiuzhang2.scaled_spec(77);
    spec.m = 6;
    spec.chi_cap = 10;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    let store =
        Arc::new(GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap());
    (store, dir)
}

fn backend_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        n2_micro: 32,
        target_batch: Some(256),
        compute: ComputePrecision::F32,
        linger_ms: 2,
        ..Default::default()
    }
}

fn backend_net(root: &Path, i: usize) -> NetConfig {
    NetConfig {
        addr: "127.0.0.1:0".into(),
        push_dir: Some(root.join(format!("pushed{i}"))),
        ..Default::default()
    }
}

fn loopback_net() -> NetConfig {
    NetConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    }
}

fn router_cfg(backends: Vec<String>) -> RouterConfig {
    RouterConfig {
        backends,
        probe_interval_ms: 30,
        degraded_after: 1,
        down_after: 2,
        backoff_base_ms: 1,
        backoff_cap_ms: 4,
        jitter_ms: 0,
        ..Default::default()
    }
}

/// `run.counters.<key>` of a metrics JSON.
fn counter(metrics: &Json, key: &str) -> f64 {
    metrics
        .get("run")
        .and_then(|r| r.get("counters"))
        .and_then(|c| c.get(key))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0)
}

fn tp_request(base: u64, of: usize, samples: u64) -> JobSpec {
    let mut spec = JobSpec::by_key(base, samples);
    spec.compute = Some(ComputePrecision::F32);
    spec.tp = Some(TpGroup {
        of,
        base,
        peers: Vec::new(),
    });
    spec
}

#[test]
fn tp_group_sink_is_byte_identical_to_a_single_backend_run() {
    let root = scratch("group");
    let (store, store_dir) = make_store(&root);
    let b1 = NetServer::start(backend_cfg(), backend_net(&root, 1)).unwrap();
    let b2 = NetServer::start(backend_cfg(), backend_net(&root, 2)).unwrap();
    let addrs = vec![b1.local_addr().to_string(), b2.local_addr().to_string()];
    let router = Router::start(router_cfg(addrs), loopback_net()).unwrap();
    let mut client = Client::connect(&router.local_addr().to_string(), &loopback_net()).unwrap();

    // Serial baseline: the full store pushed through the router, the job
    // run on whichever backend affinity chose.
    let full = client.push_store(&store_dir, 4096).unwrap();
    let base = manifest_hash_at(&store_dir).unwrap();
    assert_eq!(full.key, base);
    let mut serial = JobSpec::by_key(base, 96);
    serial.compute = Some(ComputePrecision::F32);
    let sid = client.submit(&serial).unwrap();
    let sres = client.wait(sid, Duration::from_secs(60)).unwrap().unwrap();
    assert_eq!(sres.result.get("status").unwrap().as_str(), Some("done"));
    let baseline = sres.sink.clone().expect("serial run streams a sink");

    // Shard the store 2-way and push both shards; the router records the
    // group from the announced shard identities.
    let s0 = root.join("shard0");
    let s1 = root.join("shard1");
    store.write_shard(&s0, 0, 2).unwrap();
    store.write_shard(&s1, 1, 2).unwrap();
    client.push_store(&s0, 4096).unwrap();
    client.push_store(&s1, 4096).unwrap();
    let m = client.metrics().unwrap();
    assert_eq!(counter(&m, "router_shard_pushes"), 2.0);
    assert_eq!(m.get("shard_groups").unwrap().as_f64(), Some(1.0));
    assert_eq!(m.get("shard_groups_complete").unwrap().as_f64(), Some(1.0));

    // The TP request (of + base, empty peers) resolves, runs over the
    // socket collectives, and its sink is byte-identical to the serial
    // run — same samples, same order, same bits.
    let tid = client.submit(&tp_request(base, 2, 96)).unwrap();
    let tres = client.wait(tid, Duration::from_secs(120)).unwrap().unwrap();
    assert_eq!(
        tres.result.get("status").unwrap().as_str(),
        Some("done"),
        "tp job failed: {:?}",
        tres.result.get("error")
    );
    let tp_sink = tres.sink.clone().expect("tp run streams a sink");
    assert_eq!(
        frame::pack_sink(&baseline),
        frame::pack_sink(&tp_sink),
        "TP sink must be byte-identical to the serial baseline"
    );

    // Router- and backend-side evidence the group really ran sharded.
    let m = client.metrics().unwrap();
    assert_eq!(counter(&m, "router_tp_submits"), 1.0);
    assert_eq!(counter(&m, "router_tp_rejects"), 0.0);
    let m1 = b1.service().metrics_json();
    let m2 = b2.service().metrics_json();
    assert!(
        counter(&m1, "tp_jobs") + counter(&m2, "tp_jobs") >= 2.0,
        "leader and follower both count the group"
    );
    assert!(
        counter(&m1, "tp_reduce_bytes") + counter(&m2, "tp_reduce_bytes") > 0.0,
        "partial envs crossed the wire"
    );
    assert_eq!(counter(&m1, "tp_member_failures") + counter(&m2, "tp_member_failures"), 0.0);

    drop(client);
    drop(router);
    drop(b1);
    drop(b2);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn tp_submit_refuses_typed_for_missing_groups_and_down_members() {
    let root = scratch("refuse");
    let (store, store_dir) = make_store(&root);
    let b1 = NetServer::start(backend_cfg(), backend_net(&root, 1)).unwrap();
    let b2 = NetServer::start(backend_cfg(), backend_net(&root, 2)).unwrap();
    let addrs = vec![b1.local_addr().to_string(), b2.local_addr().to_string()];
    let router = Router::start(router_cfg(addrs.clone()), loopback_net()).unwrap();
    let mut client = Client::connect(&router.local_addr().to_string(), &loopback_net()).unwrap();
    let base = manifest_hash_at(&store_dir).unwrap();

    // No shards pushed: typed refusal, not a hang or a busy.
    let err = client
        .submit(&tp_request(base, 2, 64))
        .expect_err("unregistered group must refuse");
    assert!(!err.is_busy());
    assert!(err.to_string().contains("no shard group"), "{err}");

    // Half a group is still a typed refusal naming the missing rank.
    let s0 = root.join("shard0");
    store.write_shard(&s0, 0, 2).unwrap();
    client.push_store(&s0, 4096).unwrap();
    let err = client
        .submit(&tp_request(base, 2, 64))
        .expect_err("incomplete group must refuse");
    assert!(err.to_string().contains("never pushed"), "{err}");

    // A resolved peer list from a client is rejected — placement is the
    // router's job.
    let mut forged = tp_request(base, 2, 64);
    if let Some(tp) = &mut forged.tp {
        tp.peers.push(fastmps::service::TpPeer {
            addr: addrs[0].clone(),
            key: 1,
        });
    }
    let err = client.submit(&forged).expect_err("forged peers must refuse");
    assert!(err.to_string().contains("resolved peers"), "{err}");

    // Complete the group, then kill the backend holding shard 0: once
    // the prober marks it down the submit refuses typed instead of
    // spilling the group onto backends without the shard.
    let s1 = root.join("shard1");
    store.write_shard(&s1, 1, 2).unwrap();
    client.push_store(&s1, 4096).unwrap();
    let k0 = manifest_hash_at(&s0).unwrap();
    let victim = rendezvous::rank(k0, &addrs)[0];
    let mut servers = vec![Some(b1), Some(b2)];
    drop(servers[victim].take());
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        if router.health()[victim].1 == HealthState::Down {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "victim never marked down");
        std::thread::sleep(Duration::from_millis(20));
    }
    let err = client
        .submit(&tp_request(base, 2, 64))
        .expect_err("down member must refuse");
    assert!(!err.is_busy());
    assert!(
        err.to_string().contains("spilling over"),
        "refusal should explain the no-spillover rule: {err}"
    );

    let m = client.metrics().unwrap();
    assert!(counter(&m, "router_tp_rejects") >= 4.0);
    assert_eq!(counter(&m, "router_tp_submits"), 0.0);

    drop(client);
    drop(router);
    drop(servers);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn backend_without_a_router_rejects_tp_requests_typed() {
    let root = scratch("direct");
    let (_, store_dir) = make_store(&root);
    let b = NetServer::start(backend_cfg(), backend_net(&root, 1)).unwrap();
    let mut client = Client::connect(&b.local_addr().to_string(), &loopback_net()).unwrap();
    let base = manifest_hash_at(&store_dir).unwrap();
    // A backend receiving a TP *request* (no peer list) cannot resolve
    // it — that takes the routing tier's shard map.
    let err = client
        .submit(&tp_request(base, 2, 32))
        .expect_err("direct TP request must refuse");
    assert!(!err.is_busy());
    assert!(err.to_string().contains("routing tier"), "{err}");
    drop(client);
    drop(b);
    std::fs::remove_dir_all(&root).unwrap();
}
