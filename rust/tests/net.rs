//! End-to-end network-transport integration: a loopback `NetServer` in
//! front of a real `Service`, driven through `net::client::Client` — the
//! TCP analogue of `tests/service.rs`, plus the transport-only behaviors
//! (payload streaming, typed busy backpressure, graceful drain).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use fastmps::config::{ComputePrecision, NetConfig, Preset, RunConfig, ServiceConfig};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::net::{Client, NetServer};
use fastmps::service::JobSpec;

fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fastmps-itnet-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn make_store(root: &Path) -> (Arc<GammaStore>, PathBuf) {
    let dir = root.join("store");
    let mut spec = Preset::Jiuzhang2.scaled_spec(55);
    spec.m = 6;
    spec.chi_cap = 10;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    let store =
        Arc::new(GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap());
    (store, dir)
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        n2_micro: 32,
        target_batch: Some(256),
        compute: ComputePrecision::F64,
        linger_ms: 2,
        ..Default::default()
    }
}

fn loopback_net() -> NetConfig {
    NetConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    }
}

#[test]
fn tcp_round_trip_streams_exact_sample_payloads() {
    let root = scratch("roundtrip");
    let (store, store_dir) = make_store(&root);
    let server = NetServer::start(service_cfg(), loopback_net()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();
    client.ping().unwrap();

    // Two jobs over TCP, disjoint sample streams.
    let a = client.submit(&JobSpec::new(&store_dir, 96)).unwrap();
    let mut spec_b = JobSpec::new(&store_dir, 96);
    spec_b.sample_base = 96;
    spec_b.tag = "tcp-b".into();
    let b = client.submit(&spec_b).unwrap();
    assert_ne!(a, b);

    let res_a = client.wait(a, Duration::from_secs(60)).unwrap().unwrap();
    let res_b = client.wait(b, Duration::from_secs(60)).unwrap().unwrap();
    for res in [&res_a, &res_b] {
        assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
        assert_eq!(res.result.get("done").unwrap().as_f64(), Some(96.0));
    }

    // Payload round trip, twice over: the streamed sink must equal the
    // server's own accumulator byte-for-byte…
    let sink_a = res_a.sink.as_ref().expect("payload frame for job a");
    let direct = server.service().queue().job_sink(a).unwrap();
    assert_eq!(sink_a.hist, direct.hist);
    assert_eq!(sink_a.counts, direct.counts);
    assert_eq!(sink_a.pair_sums, direct.pair_sums);

    // …and the combined statistics must equal a directly-sampled one-shot
    // coordinator run over the union range [0, 192).
    let mut rc = RunConfig::new(store.spec.clone());
    rc.n_samples = 192;
    rc.n1_macro = 192;
    rc.n2_micro = 32;
    rc.compute = ComputePrecision::F64;
    rc.store_precision = store.precision;
    let reference = data_parallel::run(&rc, &store, &[]).unwrap();
    let mut combined = sink_a.clone();
    combined.merge(res_b.sink.as_ref().unwrap());
    assert_eq!(combined.hist, reference.sink.hist);
    assert_eq!(combined.pair_sums, reference.sink.pair_sums);

    // Listing is deterministic: submit order == (time, id) order.
    let listed = client.list().unwrap();
    let jobs = listed.as_arr().unwrap();
    assert_eq!(jobs.len(), 2);
    let ids: Vec<f64> = jobs
        .iter()
        .map(|j| j.get("id").unwrap().as_f64().unwrap())
        .collect();
    assert_eq!(ids, vec![a as f64, b as f64]);
    assert_eq!(jobs[1].get("tag").unwrap().as_str(), Some("tcp-b"));

    // Live metrics carry the net counters.
    let m = client.metrics().unwrap();
    let net = m.get("net").unwrap().get("counters").unwrap();
    assert!(net.get("net_frames_in").unwrap().as_f64().unwrap() > 0.0);
    assert!(net.get("net_bytes_out").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(net.get("net_conns").unwrap().as_f64(), Some(1.0));

    drop(client);
    let final_metrics = server.shutdown();
    let run = final_metrics.get("run").unwrap().get("counters").unwrap();
    assert_eq!(run.get("jobs_completed").unwrap().as_f64(), Some(2.0));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn saturated_admission_returns_typed_busy() {
    let root = scratch("busy");
    let (_, store_dir) = make_store(&root);
    // One queue slot, and a long linger so the first job reliably holds
    // it while the second submission arrives.
    let cfg = ServiceConfig {
        max_queue: 1,
        linger_ms: 400,
        ..service_cfg()
    };
    let server = NetServer::start(cfg, loopback_net()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();

    let a = client.submit(&JobSpec::new(&store_dir, 64)).unwrap();
    let err = client
        .submit(&JobSpec::new(&store_dir, 64))
        .expect_err("second job must hit admission control");
    assert!(err.is_busy(), "typed busy, got: {err}");
    assert!(err.to_string().contains("queue full"), "{err}");

    // Busy is retryable: once the slot frees, the same submit succeeds.
    let res_a = client.wait(a, Duration::from_secs(60)).unwrap().unwrap();
    assert_eq!(res_a.result.get("status").unwrap().as_str(), Some("done"));
    let c = client.submit(&JobSpec::new(&store_dir, 32)).unwrap();
    assert!(client.wait(c, Duration::from_secs(60)).unwrap().is_some());

    let m = client.metrics().unwrap();
    let net = m.get("net").unwrap().get("counters").unwrap();
    assert!(
        net.get("net_rejects_busy").unwrap().as_f64().unwrap() >= 1.0,
        "busy rejection counted"
    );
    drop(client);
    drop(server);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn connection_pool_bound_rejects_then_recovers() {
    let root = scratch("pool");
    let (_, _store_dir) = make_store(&root);
    let net = NetConfig {
        max_conns: 1,
        ..loopback_net()
    };
    let server = NetServer::start(service_cfg(), net.clone()).unwrap();
    let addr = server.local_addr().to_string();

    let mut first = Client::connect(&addr, &net).unwrap();
    first.ping().unwrap();
    // Second connection is accepted at the TCP level but rejected with a
    // typed busy frame before any op is served.
    let mut second = Client::connect(&addr, &net).unwrap();
    let err = second.ping().expect_err("pool bound must reject");
    assert!(err.is_busy(), "typed busy, got: {err}");

    // Dropping the first connection frees the slot (the server reaps the
    // closed socket on its next read); a fresh connection then works.
    drop(first);
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let mut retry = Client::connect(&addr, &net).unwrap();
        if retry.ping().is_ok() {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "slot never freed after client disconnect"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    drop(second);
    drop(server);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn graceful_shutdown_drains_in_flight_jobs() {
    let root = scratch("drain");
    let (_, store_dir) = make_store(&root);
    // A long linger keeps the job in flight when shutdown arrives.
    let cfg = ServiceConfig {
        linger_ms: 300,
        ..service_cfg()
    };
    let server = NetServer::start(cfg, loopback_net()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();

    let id = client.submit(&JobSpec::new(&store_dir, 96)).unwrap();
    // Shutdown races the linger window: the reply must still prove the
    // accepted job ran to completion before the server stopped.
    let metrics = client.shutdown_server(Duration::from_secs(120)).unwrap();
    let run = metrics.get("run").unwrap().get("counters").unwrap();
    assert_eq!(
        run.get("jobs_completed").unwrap().as_f64(),
        Some(1.0),
        "in-flight job drained, not dropped"
    );
    assert_eq!(run.get("jobs_failed").and_then(|v| v.as_f64()), Some(0.0));
    let view = server.service().queue().status(id).unwrap();
    assert_eq!(view.status.as_str(), "done");
    assert_eq!(view.done, 96);
    assert!(server.shutdown_requested());

    // New work after the drain is refused (shutdown, not busy).
    let mut late = Client::connect(&addr, &loopback_net()).unwrap();
    let err = late
        .submit(&JobSpec::new(&store_dir, 8))
        .expect_err("post-drain submit must fail");
    assert!(!err.is_busy());
    assert!(err.to_string().contains("shutting down"), "{err}");

    drop(client);
    drop(late);
    let final_metrics = server.shutdown();
    let run = final_metrics.get("run").unwrap().get("counters").unwrap();
    assert_eq!(run.get("jobs_completed").unwrap().as_f64(), Some(1.0));
    std::fs::remove_dir_all(&root).unwrap();
}
