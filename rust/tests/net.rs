//! End-to-end network-transport integration: a loopback `NetServer` in
//! front of a real `Service`, driven through `net::client::Client` — the
//! TCP analogue of `tests/service.rs`, plus the transport-only behaviors
//! (payload streaming, typed busy backpressure, graceful drain).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use fastmps::config::{ComputePrecision, NetConfig, Preset, RunConfig, ServiceConfig};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::net::{Client, NetServer};
use fastmps::service::JobSpec;

fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fastmps-itnet-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn make_store(root: &Path) -> (Arc<GammaStore>, PathBuf) {
    let dir = root.join("store");
    let mut spec = Preset::Jiuzhang2.scaled_spec(55);
    spec.m = 6;
    spec.chi_cap = 10;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    let store =
        Arc::new(GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap());
    (store, dir)
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        n2_micro: 32,
        target_batch: Some(256),
        compute: ComputePrecision::F64,
        linger_ms: 2,
        ..Default::default()
    }
}

fn loopback_net() -> NetConfig {
    NetConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    }
}

#[test]
fn tcp_round_trip_streams_exact_sample_payloads() {
    let root = scratch("roundtrip");
    let (store, store_dir) = make_store(&root);
    let server = NetServer::start(service_cfg(), loopback_net()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();
    client.ping().unwrap();

    // Two jobs over TCP, disjoint sample streams.
    let a = client.submit(&JobSpec::new(&store_dir, 96)).unwrap();
    let mut spec_b = JobSpec::new(&store_dir, 96);
    spec_b.sample_base = 96;
    spec_b.tag = "tcp-b".into();
    let b = client.submit(&spec_b).unwrap();
    assert_ne!(a, b);

    let res_a = client.wait(a, Duration::from_secs(60)).unwrap().unwrap();
    let res_b = client.wait(b, Duration::from_secs(60)).unwrap().unwrap();
    for res in [&res_a, &res_b] {
        assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
        assert_eq!(res.result.get("done").unwrap().as_f64(), Some(96.0));
    }

    // Payload round trip, twice over: the streamed sink must equal the
    // server's own accumulator byte-for-byte…
    let sink_a = res_a.sink.as_ref().expect("payload frame for job a");
    let direct = server.service().queue().job_sink(a).unwrap();
    assert_eq!(sink_a.hist, direct.hist);
    assert_eq!(sink_a.counts, direct.counts);
    assert_eq!(sink_a.pair_sums, direct.pair_sums);

    // …and the combined statistics must equal a directly-sampled one-shot
    // coordinator run over the union range [0, 192).
    let mut rc = RunConfig::new(store.spec.clone());
    rc.n_samples = 192;
    rc.n1_macro = 192;
    rc.n2_micro = 32;
    rc.compute = ComputePrecision::F64;
    rc.store_precision = store.precision;
    let reference = data_parallel::run(&rc, &store, &[]).unwrap();
    let mut combined = sink_a.clone();
    combined.merge(res_b.sink.as_ref().unwrap());
    assert_eq!(combined.hist, reference.sink.hist);
    assert_eq!(combined.pair_sums, reference.sink.pair_sums);

    // Listing is deterministic: submit order == (time, id) order.
    let listed = client.list().unwrap();
    let jobs = listed.as_arr().unwrap();
    assert_eq!(jobs.len(), 2);
    let ids: Vec<f64> = jobs
        .iter()
        .map(|j| j.get("id").unwrap().as_f64().unwrap())
        .collect();
    assert_eq!(ids, vec![a as f64, b as f64]);
    assert_eq!(jobs[1].get("tag").unwrap().as_str(), Some("tcp-b"));

    // Live metrics carry the net counters.
    let m = client.metrics().unwrap();
    let net = m.get("net").unwrap().get("counters").unwrap();
    assert!(net.get("net_frames_in").unwrap().as_f64().unwrap() > 0.0);
    assert!(net.get("net_bytes_out").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(net.get("net_conns").unwrap().as_f64(), Some(1.0));

    drop(client);
    let final_metrics = server.shutdown();
    let run = final_metrics.get("run").unwrap().get("counters").unwrap();
    assert_eq!(run.get("jobs_completed").unwrap().as_f64(), Some(2.0));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn saturated_admission_returns_typed_busy() {
    let root = scratch("busy");
    let (_, store_dir) = make_store(&root);
    // One queue slot, and a long linger so the first job reliably holds
    // it while the second submission arrives.
    let cfg = ServiceConfig {
        max_queue: 1,
        linger_ms: 400,
        ..service_cfg()
    };
    let server = NetServer::start(cfg, loopback_net()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();

    let a = client.submit(&JobSpec::new(&store_dir, 64)).unwrap();
    let err = client
        .submit(&JobSpec::new(&store_dir, 64))
        .expect_err("second job must hit admission control");
    assert!(err.is_busy(), "typed busy, got: {err}");
    assert!(err.to_string().contains("queue full"), "{err}");

    // Busy is retryable: once the slot frees, the same submit succeeds.
    let res_a = client.wait(a, Duration::from_secs(60)).unwrap().unwrap();
    assert_eq!(res_a.result.get("status").unwrap().as_str(), Some("done"));
    let c = client.submit(&JobSpec::new(&store_dir, 32)).unwrap();
    assert!(client.wait(c, Duration::from_secs(60)).unwrap().is_some());

    let m = client.metrics().unwrap();
    let net = m.get("net").unwrap().get("counters").unwrap();
    assert!(
        net.get("net_rejects_busy").unwrap().as_f64().unwrap() >= 1.0,
        "busy rejection counted"
    );
    drop(client);
    drop(server);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn connection_pool_bound_rejects_then_recovers() {
    let root = scratch("pool");
    let (_, _store_dir) = make_store(&root);
    let net = NetConfig {
        max_conns: 1,
        ..loopback_net()
    };
    let server = NetServer::start(service_cfg(), net.clone()).unwrap();
    let addr = server.local_addr().to_string();

    let mut first = Client::connect(&addr, &net).unwrap();
    first.ping().unwrap();
    // Second connection is accepted at the TCP level but rejected with a
    // typed busy frame before any op is served.
    let mut second = Client::connect(&addr, &net).unwrap();
    let err = second.ping().expect_err("pool bound must reject");
    assert!(err.is_busy(), "typed busy, got: {err}");

    // Dropping the first connection frees the slot (the server reaps the
    // closed socket on its next read); a fresh connection then works.
    drop(first);
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let mut retry = Client::connect(&addr, &net).unwrap();
        if retry.ping().is_ok() {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "slot never freed after client disconnect"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    drop(second);
    drop(server);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn version_skew_rejected_with_clear_errors_on_both_sides() {
    use fastmps::net::frame::{self, Frame, FrameReader};
    use std::io::{BufReader, Read, Write};
    use std::net::{TcpListener, TcpStream};

    // Client side: a peer announcing VERSION+1 is refused at connect.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut preamble = Vec::from(frame::MAGIC);
        preamble.push(frame::VERSION + 1);
        s.write_all(&preamble).unwrap();
        let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
        let mut buf = [0u8; 64];
        let _ = s.read(&mut buf); // client's preamble, then its hangup
    });
    let err = Client::connect(&addr, &loopback_net()).expect_err("newer peer must be rejected");
    let msg = err.to_string();
    assert!(msg.contains("version"), "clear version error, got: {msg}");
    fake.join().unwrap();

    // Server side: a raw client announcing VERSION+1 gets a clear error
    // frame back before the connection closes.
    let server = NetServer::start(service_cfg(), loopback_net()).unwrap();
    let mut raw = TcpStream::connect(server.local_addr()).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut bad = Vec::from(frame::MAGIC);
    bad.push(frame::VERSION + 1);
    raw.write_all(&bad).unwrap();
    let mut r = FrameReader::new(BufReader::new(raw.try_clone().unwrap()), 1 << 20);
    assert_eq!(r.read_preamble().unwrap(), frame::VERSION);
    match r.read_frame().unwrap() {
        Frame::Ctrl(j) => {
            assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
            let e = j.get("error").unwrap().as_str().unwrap();
            assert!(e.contains("version"), "clear version error, got: {e}");
        }
        other => panic!("expected error ctrl frame, got {other:?}"),
    }
    drop(raw);
    drop(server);
}

#[test]
fn interrupted_push_leaves_no_partial_store() {
    use fastmps::io::StoreStreamSource;
    use fastmps::net::frame::{self, Frame, FrameReader, FrameWriter};
    use fastmps::util::json::Json;
    use fastmps::util::Fnv1a;
    use std::io::{BufReader, BufWriter};
    use std::net::TcpStream;
    use std::time::Instant;

    let root = scratch("pushabort");
    let (_, store_dir) = make_store(&root);
    let push_dir = root.join("pushed");
    let net = NetConfig {
        push_dir: Some(push_dir.clone()),
        // Small read timeout → ~1 s push stall cap: the idle-abort case
        // stays fast.
        read_timeout_ms: 50,
        ..loopback_net()
    };
    let server = NetServer::start(service_cfg(), net.clone()).unwrap();
    let addr = server.local_addr().to_string();

    // First chunk of a real push stream, hand-built so the transfer can
    // die mid-flight.
    let chunk_bytes = 1024usize;
    let mut src = StoreStreamSource::open(&store_dir).unwrap();
    let total = src.total_len();
    let chunks = total.div_ceil(chunk_bytes as u64);
    assert!(chunks > 1, "store must span multiple chunks");
    let mut buf = vec![0u8; chunk_bytes];
    let n = src.read_chunk(&mut buf).unwrap();
    let mut fnv = Fnv1a::new();
    fnv.update(&buf[..n]);
    let chunk0 = frame::encode_chunk(0, fnv.digest(), &buf[..n]);
    let key = fastmps::io::manifest_hash_at(&store_dir).unwrap();
    let begin = Json::obj(vec![
        ("op", Json::Str("push_begin".into())),
        ("key", Json::Str(format!("{key:016x}"))),
        ("total_bytes", Json::Num(total as f64)),
        ("chunks", Json::Num(chunks as f64)),
    ]);

    let start_push = |die_by_drop: bool| {
        let stream = TcpStream::connect(&addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut w = FrameWriter::new(BufWriter::new(stream.try_clone().unwrap()));
        let mut r = FrameReader::new(BufReader::new(stream), 64 << 20);
        w.write_preamble().unwrap();
        r.read_preamble().unwrap();
        w.write_ctrl(&begin).unwrap();
        match r.read_frame().unwrap() {
            Frame::Ctrl(j) => {
                assert_eq!(j.get("type").unwrap().as_str(), Some("push_ready"));
                assert_eq!(j.get("dedup").unwrap().as_bool(), Some(false));
            }
            other => panic!("expected push_ready, got {other:?}"),
        }
        w.write_chunk(&chunk0).unwrap();
        if die_by_drop {
            return; // connection drop mid-transfer
        }
        // Idle mid-transfer: the server's stall cap must abort the push
        // with an error frame (or close the socket outright).
        match r.read_frame() {
            Ok(Frame::Ctrl(j)) => {
                let e = j.get("error").unwrap().as_str().unwrap();
                assert!(e.contains("stalled"), "stall abort, got: {e}");
            }
            Ok(other) => panic!("expected stall error, got {other:?}"),
            Err(_) => {} // server closed on us — equally fine
        }
    };

    start_push(true); // connection drop
    start_push(false); // idle timeout

    // Neither failure may leave anything behind: no installed store, no
    // staging leftovers, nothing in the cache.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let leftovers: Vec<String> = std::fs::read_dir(&push_dir)
            .map(|rd| {
                rd.flatten()
                    .map(|e| e.file_name().to_string_lossy().into_owned())
                    .collect()
            })
            .unwrap_or_default();
        if leftovers.is_empty() {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "staging never cleaned: {leftovers:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(!server.service().cache().knows(key), "no partial install");

    // Both aborts are visible in the metrics, and a full push still
    // succeeds afterwards on a fresh connection (with a forgiving RPC
    // deadline — the tight one above was only to speed the stall cap).
    let client_net = NetConfig {
        read_timeout_ms: 2000,
        ..net.clone()
    };
    let mut client = Client::connect(&addr, &client_net).unwrap();
    let report = client.push_store(&store_dir, chunk_bytes).unwrap();
    assert!(!report.dedup);
    assert!(server.service().cache().knows(key));
    let m = client.metrics().unwrap();
    let netc = m.get("net").unwrap().get("counters").unwrap();
    assert!(
        netc.get("net_push_aborts").unwrap().as_f64().unwrap() >= 2.0,
        "aborts counted"
    );
    assert_eq!(netc.get("net_pushes").unwrap().as_f64(), Some(1.0));

    drop(client);
    drop(server);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn trace_field_skew_old_client_runs_untraced() {
    use fastmps::net::frame::{Frame, FrameReader, FrameWriter};
    use fastmps::util::json::Json;
    use std::io::{BufReader, BufWriter};
    use std::net::TcpStream;

    // An "old client" — a hand-rolled submit whose job-spec JSON predates
    // the optional "trace" field (and carries a future field of its own:
    // skew tolerance must cut both ways). Same preamble, same version.
    let root = scratch("skew-oldclient");
    let (_, store_dir) = make_store(&root);
    let server = NetServer::start(service_cfg(), loopback_net()).unwrap();
    let addr = server.local_addr().to_string();

    let stream = TcpStream::connect(&addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut w = FrameWriter::new(BufWriter::new(stream.try_clone().unwrap()));
    let mut r = FrameReader::new(BufReader::new(stream), 1 << 20);
    w.write_preamble().unwrap();
    r.read_preamble().unwrap();
    let msg = Json::obj(vec![
        ("op", Json::Str("submit".into())),
        (
            "job",
            Json::obj(vec![
                ("data", Json::Str(store_dir.display().to_string())),
                ("samples", Json::Num(32.0)),
                ("from_the_future", Json::Str("ignored".into())),
            ]),
        ),
    ]);
    w.write_ctrl(&msg).unwrap();
    let id = match r.read_frame().unwrap() {
        Frame::Ctrl(j) => {
            assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
            j.get("id").unwrap().as_f64().unwrap() as u64
        }
        other => panic!("expected submitted ctrl, got {other:?}"),
    };

    // The job runs to completion, observed over a normal client…
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();
    let res = client.wait(id, Duration::from_secs(60)).unwrap().unwrap();
    assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
    // …untraced: no trace id anywhere, but the job-keyed server spans
    // (queue wait, worker batch, sink encode) are still replayable.
    assert!(matches!(res.result.get("trace"), Some(Json::Null)));
    let reply = client.trace_events(id, 0).unwrap();
    assert!(matches!(reply.get("trace"), Some(Json::Null)));
    let events = reply.get("events").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "job-keyed events survive without a trace id");
    assert!(
        events.iter().all(|e| e.get("trace").is_none()),
        "untraced events must omit the trace key"
    );

    drop(client);
    drop(server);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn trace_field_skew_old_server_ignores_it() {
    use fastmps::net::frame::{Frame, FrameReader, FrameWriter};
    use fastmps::util::json::Json;
    use std::io::{BufReader, BufWriter};
    use std::net::TcpListener;

    // An "old server" — a scripted peer with no notion of the "trace"
    // key. JSON readers skip unknown keys, so a traced submit must go
    // through unchanged; the job just runs untraced on the far side.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let old_server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut w = FrameWriter::new(BufWriter::new(stream.try_clone().unwrap()));
        let mut r = FrameReader::new(BufReader::new(stream), 1 << 20);
        w.write_preamble().unwrap();
        r.read_preamble().unwrap();
        let msg = match r.read_frame().unwrap() {
            Frame::Ctrl(j) => j,
            other => panic!("expected ctrl frame, got {other:?}"),
        };
        assert_eq!(msg.get("op").unwrap().as_str(), Some("submit"));
        let job = msg.get("job").unwrap();
        // The new field is on the wire…
        assert!(job.get("trace").and_then(|v| v.as_str()).is_some());
        // …but an old reader never looks at it: drop the key wholesale
        // and the spec must still parse from the remaining fields.
        let mut pruned = match job.clone() {
            Json::Obj(m) => m,
            other => panic!("job spec not an object: {other:?}"),
        };
        pruned.remove("trace");
        let spec = JobSpec::from_json(&Json::Obj(pruned)).unwrap();
        assert_eq!(spec.n_samples, 16);
        w.write_ctrl(&Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("type", Json::Str("submitted".into())),
            ("id", Json::Num(7.0)),
        ]))
        .unwrap();
    });

    let mut client = Client::connect(&addr, &loopback_net()).unwrap();
    let (id, trace) = client
        .submit_traced(&JobSpec::new("/tmp/ignored", 16))
        .unwrap();
    assert_eq!(id, 7);
    assert_ne!(trace, 0, "client keeps its trace id even when unechoed");
    old_server.join().unwrap();
}

#[test]
fn workload_field_skew_old_client_runs_as_gbs() {
    use fastmps::net::frame::{Frame, FrameReader, FrameWriter};
    use fastmps::util::json::Json;
    use std::io::{BufReader, BufWriter};
    use std::net::TcpStream;

    // An "old client" — a hand-rolled submit whose job-spec JSON predates
    // the optional "workload" field. Every store was GBS back then, so
    // the server must default the declaration to gbs and run the job
    // unchanged against a GBS store.
    let root = scratch("skew-workload");
    let (_, store_dir) = make_store(&root);
    let server = NetServer::start(service_cfg(), loopback_net()).unwrap();
    let addr = server.local_addr().to_string();

    let stream = TcpStream::connect(&addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut w = FrameWriter::new(BufWriter::new(stream.try_clone().unwrap()));
    let mut r = FrameReader::new(BufReader::new(stream), 1 << 20);
    w.write_preamble().unwrap();
    r.read_preamble().unwrap();
    let msg = Json::obj(vec![
        ("op", Json::Str("submit".into())),
        (
            "job",
            Json::obj(vec![
                ("data", Json::Str(store_dir.display().to_string())),
                ("samples", Json::Num(32.0)),
            ]),
        ),
    ]);
    w.write_ctrl(&msg).unwrap();
    let id = match r.read_frame().unwrap() {
        Frame::Ctrl(j) => {
            assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
            j.get("id").unwrap().as_f64().unwrap() as u64
        }
        other => panic!("expected submitted ctrl, got {other:?}"),
    };

    // The job runs to completion as GBS and says so in the view.
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();
    let res = client.wait(id, Duration::from_secs(60)).unwrap().unwrap();
    assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
    assert_eq!(res.result.get("workload").unwrap().as_str(), Some("gbs"));
    let view = client.status(id).unwrap();
    assert_eq!(view.get("workload").unwrap().as_str(), Some("gbs"));

    drop(client);
    drop(server);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn explicit_gbs_workload_is_byte_identical_on_the_wire() {
    use fastmps::mps::workload::WorkloadKind;
    use fastmps::util::json::Json;

    // A new client declaring gbs explicitly must emit exactly the bytes a
    // pre-workload client would have: the default tag is omitted, not
    // serialized as "workload": "gbs" — so dedup, affinity, and old
    // servers all see the same submit.
    let mut tagged = JobSpec::new("/data/store", 64);
    tagged.workload = WorkloadKind::Gbs;
    let untagged = JobSpec::new("/data/store", 64);
    let tagged_wire = tagged.to_json().dump();
    let untagged_wire = untagged.to_json().dump();
    assert_eq!(tagged_wire, untagged_wire, "explicit gbs must not change the wire form");
    assert!(
        !tagged_wire.contains("workload"),
        "gbs submits carry no workload key: {tagged_wire}"
    );

    // And the round trip through the pre-workload wire form is lossless:
    // parsing a spec with no workload key yields gbs, which re-serializes
    // to the identical bytes.
    let parsed = JobSpec::from_json(&tagged.to_json()).unwrap();
    assert_eq!(parsed.workload, WorkloadKind::Gbs);
    assert_eq!(parsed.to_json().dump(), untagged_wire);

    // A qubit declaration, by contrast, is on the wire and survives the
    // round trip.
    let mut qubit = JobSpec::new("/data/store", 64);
    qubit.workload = WorkloadKind::Qubit;
    let qubit_wire = qubit.to_json();
    assert_eq!(
        qubit_wire.get("workload").and_then(Json::as_str),
        Some("qubit")
    );
    assert_eq!(
        JobSpec::from_json(&qubit_wire).unwrap().workload,
        WorkloadKind::Qubit
    );
}

#[test]
fn trace_op_replays_job_timeline_end_to_end() {
    use std::collections::BTreeSet;

    let root = scratch("traceop");
    let (_, store_dir) = make_store(&root);
    let server = NetServer::start(service_cfg(), loopback_net()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();

    let (id, trace) = client
        .submit_traced(&JobSpec::new(&store_dir, 64))
        .unwrap();
    assert_ne!(trace, 0);
    let res = client.wait(id, Duration::from_secs(60)).unwrap().unwrap();
    assert_eq!(res.result.get("status").unwrap().as_str(), Some("done"));
    let hex = format!("{trace:016x}");
    assert_eq!(
        res.result.get("trace").unwrap().as_str(),
        Some(hex.as_str()),
        "trace id round-trips through the job view"
    );

    // Query by job id alone: the server resolves the trace id itself.
    let by_job = client.trace_events(id, 0).unwrap();
    assert_eq!(by_job.get("trace").unwrap().as_str(), Some(hex.as_str()));
    let events = by_job.get("events").unwrap().as_arr().unwrap().to_vec();
    assert!(!events.is_empty());

    // The timeline spans the server-side layers end to end, in merged
    // time order.
    let layers: BTreeSet<&str> = events
        .iter()
        .map(|e| e.get("layer").unwrap().as_str().unwrap())
        .collect();
    for want in ["net", "queue", "batcher", "worker", "engine", "sink"] {
        assert!(layers.contains(want), "missing {want} layer in {layers:?}");
    }
    let names: BTreeSet<&str> = events
        .iter()
        .map(|e| e.get("name").unwrap().as_str().unwrap())
        .collect();
    for want in ["op_submit", "admit", "queue_wait", "batch", "job_done", "encode"] {
        assert!(names.contains(want), "missing {want} event in {names:?}");
    }
    let ts: Vec<f64> = events
        .iter()
        .map(|e| e.get("t_us").unwrap().as_f64().unwrap())
        .collect();
    assert!(ts.windows(2).all(|p| p[0] <= p[1]), "events sorted by time");

    // Query by trace id alone: at least the same timeline (plus any ops
    // recorded since, e.g. the by-job trace query itself).
    let by_trace = client.trace_events(0, trace).unwrap();
    let n_by_trace = by_trace.get("events").unwrap().as_arr().unwrap().len();
    assert!(n_by_trace >= events.len(), "{n_by_trace} < {}", events.len());

    // Both renderings work off the same reply: a human timeline and a
    // chrome://tracing export with one entry per event.
    let human = fastmps::trace::render_human(&by_job);
    assert!(human.contains(&hex), "{human}");
    assert!(human.contains("queue_wait"), "{human}");
    let chrome = fastmps::trace::chrome_trace(&by_job);
    let te = chrome.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(te.len(), events.len());

    drop(client);
    drop(server);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn graceful_shutdown_drains_in_flight_jobs() {
    let root = scratch("drain");
    let (_, store_dir) = make_store(&root);
    // A long linger keeps the job in flight when shutdown arrives.
    let cfg = ServiceConfig {
        linger_ms: 300,
        ..service_cfg()
    };
    let server = NetServer::start(cfg, loopback_net()).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr, &loopback_net()).unwrap();

    let id = client.submit(&JobSpec::new(&store_dir, 96)).unwrap();
    // Shutdown races the linger window: the reply must still prove the
    // accepted job ran to completion before the server stopped.
    let metrics = client.shutdown_server(Duration::from_secs(120)).unwrap();
    let run = metrics.get("run").unwrap().get("counters").unwrap();
    assert_eq!(
        run.get("jobs_completed").unwrap().as_f64(),
        Some(1.0),
        "in-flight job drained, not dropped"
    );
    assert_eq!(run.get("jobs_failed").and_then(|v| v.as_f64()), Some(0.0));
    let view = server.service().queue().status(id).unwrap();
    assert_eq!(view.status.as_str(), "done");
    assert_eq!(view.done, 96);
    assert!(server.shutdown_requested());

    // New work after the drain is refused (shutdown, not busy).
    let mut late = Client::connect(&addr, &loopback_net()).unwrap();
    let err = late
        .submit(&JobSpec::new(&store_dir, 8))
        .expect_err("post-drain submit must fail");
    assert!(!err.is_busy());
    assert!(err.to_string().contains("shutting down"), "{err}");

    drop(client);
    drop(late);
    let final_metrics = server.shutdown();
    let run = final_metrics.get("run").unwrap().get("counters").unwrap();
    assert_eq!(run.get("jobs_completed").unwrap().as_f64(), Some(1.0));
    std::fs::remove_dir_all(&root).unwrap();
}
