//! Cross-layer integration tests: AOT artifacts × PJRT runtime ×
//! coordinators × validation.
//!
//! Requires `make artifacts` (skipped gracefully when absent so
//! `cargo test` works before the first build).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::{data_parallel, model_parallel, tensor_parallel};
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::mps::gbs::GbsSpec;

fn artifacts_dir() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn make_store(tag: &str, spec: &GbsSpec) -> (Arc<GammaStore>, PathBuf) {
    let dir = std::env::temp_dir().join(format!("fastmps-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store =
        Arc::new(GammaStore::create(&dir, spec, StorePrecision::F32, StoreCodec::Raw).unwrap());
    (store, dir)
}

fn small_spec(m: usize, chi: usize, sigma: f64) -> GbsSpec {
    let mut spec = Preset::Jiuzhang2.scaled_spec(42);
    spec.m = m;
    spec.chi_cap = chi;
    spec.decay_k = 0.02;
    spec.displacement_sigma = sigma;
    spec
}

fn base_cfg(store: &GammaStore, samples: u64) -> RunConfig {
    let mut cfg = RunConfig::new(store.spec.clone());
    cfg.n_samples = samples;
    cfg.n1_macro = 256;
    cfg.n2_micro = 256;
    cfg.engine = EngineKind::Native;
    cfg.compute = ComputePrecision::F32;
    cfg.scaling = ScalingMode::PerSample;
    cfg.store_precision = store.precision;
    cfg
}

#[test]
fn xla_engine_matches_native_outcomes() {
    let Some(art) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let (store, dir) = make_store("xla-match", &small_spec(16, 64, 0.0));
    let mut native = base_cfg(&store, 512);
    let report_native = data_parallel::run(&native, &store, &[]).unwrap();
    native.engine = EngineKind::Xla;
    native.artifacts_dir = art;
    let report_xla = data_parallel::run(&native, &store, &[]).unwrap();
    // Identical seeds, identical f32 pipeline ⇒ identical histograms (a
    // handful of knife-edge flips tolerated).
    let total: u64 = report_native.sink.counts.iter().sum();
    let mut diff = 0u64;
    for (a, b) in report_native.sink.hist.iter().zip(&report_xla.sink.hist) {
        for (x, y) in a.iter().zip(b) {
            diff += x.abs_diff(*y);
        }
    }
    assert!(
        diff * 200 <= total,
        "{diff} outcome-count moves out of {total}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn xla_engine_runs_displaced_path() {
    let Some(art) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let (store, dir) = make_store("xla-disp", &small_spec(12, 64, 0.3));
    let mut cfg = base_cfg(&store, 256);
    cfg.engine = EngineKind::Xla;
    cfg.artifacts_dir = art;
    let rep = data_parallel::run(&cfg, &store, &[]).unwrap();
    assert_eq!(rep.sink.total_samples(), 256);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn all_three_schemes_agree_on_statistics() {
    let (store, dir) = make_store("schemes", &small_spec(10, 24, 0.0));
    let mut cfg = base_cfg(&store, 256);
    cfg.compute = ComputePrecision::F64;
    let dp = data_parallel::run(&cfg, &store, &[]).unwrap();
    let mp = model_parallel::run(&cfg, &store).unwrap();
    let mut tp_cfg = cfg.clone();
    tp_cfg.p2 = 2;
    let tp = tensor_parallel::run(&tp_cfg, &store).unwrap();
    assert_eq!(dp.sink.hist, mp.sink.hist, "DP vs MP");
    assert_eq!(dp.sink.hist, tp.sink.hist, "DP vs TP");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn validation_slopes_near_one_through_full_stack() {
    let (store, dir) = make_store("validate", &small_spec(12, 16, 0.0));
    let mut cfg = base_cfg(&store, 8192);
    cfg.n1_macro = 2048;
    cfg.p1 = 2;
    cfg.compute = ComputePrecision::F64;
    let rep = data_parallel::run(&cfg, &store, &[]).unwrap();
    let mps = store.load_all().unwrap();
    let v = fastmps::validate::validate(&mps, &rep.sink).unwrap();
    assert!(
        (v.first_order_slope - 1.0).abs() < 0.06,
        "slope {}",
        v.first_order_slope
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn f16_store_lz_full_pipeline() {
    let dir = std::env::temp_dir().join(format!("fastmps-it-f16lz-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = small_spec(10, 32, 0.0);
    let store = Arc::new(
        GammaStore::create(&dir, &spec, StorePrecision::F16, StoreCodec::Lz).unwrap(),
    );
    let cfg = base_cfg(&store, 256);
    let rep = data_parallel::run(&cfg, &store, &[]).unwrap();
    assert_eq!(rep.sink.total_samples(), 256);
    assert_eq!(rep.dead_rows, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn throttled_disk_accounts_io_time() {
    let (store, dir) = make_store("disk", &small_spec(8, 32, 0.0));
    let mut cfg = base_cfg(&store, 256);
    cfg.disk_bw = Some(50e6); // 50 MB/s
    let rep = data_parallel::run(&cfg, &store, &[]).unwrap();
    let expect = store.total_bytes() as f64 / 50e6;
    let io = rep.metrics.phase("io_virtual");
    assert!(
        io >= expect * 0.9,
        "io_virtual {io} < expected {expect}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn underflow_injection_is_detected_not_silent() {
    // Failure injection: brutal decay with no rescaling in f32 must be
    // *visible* via dead_rows, while the run itself completes.
    let mut spec = small_spec(12, 16, 0.0);
    spec.decay_k = 4.0;
    let (store, dir) = make_store("underflow", &spec);
    let mut cfg = base_cfg(&store, 128);
    cfg.scaling = ScalingMode::None;
    let rep = data_parallel::run(&cfg, &store, &[]).unwrap();
    assert!(rep.dead_rows > 0, "collapse must be reported");
    // FastMPS per-sample scaling on the same data survives.
    cfg.scaling = ScalingMode::PerSample;
    let ok = data_parallel::run(&cfg, &store, &[]).unwrap();
    assert_eq!(ok.dead_rows, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn scaling_efficiency_of_dp_threads() {
    // Weak check of Fig. 12's shape on real threads: 2 workers should not
    // be slower than 1 worker on the same total work (generous margin for
    // CI noise).
    let (store, dir) = make_store("scaleff", &small_spec(12, 48, 0.0));
    let mut cfg = base_cfg(&store, 2048);
    cfg.n1_macro = 512;
    cfg.p1 = 1;
    let t1 = data_parallel::run(&cfg, &store, &[]).unwrap().wall;
    cfg.p1 = 2;
    let t2 = data_parallel::run(&cfg, &store, &[]).unwrap().wall;
    assert!(t2 < t1 * 1.2, "p1=2 took {t2}s vs p1=1 {t1}s");
    std::fs::remove_dir_all(&dir).unwrap();
}
